"""Unified decoder-only LM across all assigned architecture families.

API:
    model = DecoderLM(cfg)
    specs  = model.param_specs()              # ParamSpec pytree
    params = init_params(specs, key)          # materialize (smoke/examples)
    loss   = model.loss(params, batch)        # training loss
    logits, cache = model.prefill(params, inputs)
    logits, cache = model.decode_step(params, cache, inputs, pos)
    cache_specs   = model.cache_specs(batch, max_seq)  # ParamSpec pytree

All paths are pure jnp/lax — lowerable under pjit on any mesh; sharding
comes from ParamSpec logical axes + dist.constrain boundary hints.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.shard import constrain
from repro.kernels.ops import qmatmul_xla as qmm
from repro.quant.qarray import QTensor, dequant_rows, maybe_dequantize as deq

from .attention import empty_cache_spec, paged_cache_spec
from .blocks import (mamba_block, mamba_block_decode, mamba_block_serve,
                     mamba_block_specs, mlstm_block, mlstm_block_decode,
                     mlstm_block_serve, mlstm_block_specs, norm_specs,
                     apply_norm, slstm_block, slstm_block_decode,
                     slstm_block_serve, slstm_block_specs, transformer_block,
                     transformer_block_decode, transformer_block_paged,
                     transformer_block_specs, zamba_lora_specs,
                     zamba_shared_block, zamba_shared_block_decode,
                     zamba_shared_block_paged, zamba_shared_specs)
from .common import (BATCH, FSDP, KV_SEQ, NONE, TP, ParamSpec,
                     cross_entropy_loss, init_params, param_count,
                     scan_layers, softcap, stack_specs)
from .config import ModelConfig
from .ssm import mamba2_cache_spec, mlstm_cache_spec, slstm_cache_spec

Params = Dict[str, Any]


def _cache_param_specs(struct_tree, batch_axes_map) -> Any:
    """ShapeDtypeStruct tree + per-leaf-name axes -> ParamSpec tree."""
    return jax.tree_util.tree_map(
        lambda s, ax: ParamSpec(tuple(s.shape), s.dtype, ax, init="zeros"),
        struct_tree, batch_axes_map)


class DecoderLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ==================================================================
    # parameter specs
    # ==================================================================
    def param_specs(self) -> Params:
        cfg = self.cfg
        sp: Params = {}
        # (for frontend-stub archs the table still serves as the LM head)
        sp["embed"] = ParamSpec((cfg.vocab, cfg.d_model), axes=(TP, FSDP),
                                init="embed", scale=cfg.d_model ** -0.5)
        if not cfg.tie_embeddings:
            sp["head"] = ParamSpec((cfg.d_model, cfg.vocab), axes=(FSDP, TP))
        sp["ln_final"] = norm_specs(cfg)

        if cfg.family in ("dense", "moe"):
            n_first = (cfg.moe.first_dense_layers
                       if (cfg.moe and cfg.moe.first_dense_layers) else 0)
            if n_first:
                dense_ff = getattr(cfg.moe, "first_dense_d_ff", cfg.d_ff)
                sp["first_blocks"] = stack_specs(
                    transformer_block_specs(cfg, dense_ffn_override=dense_ff),
                    n_first)
            sp["blocks"] = stack_specs(transformer_block_specs(cfg),
                                       cfg.n_layers - n_first)
        elif cfg.family == "xlstm":
            per = cfg.ssm.slstm_every
            n_groups = cfg.n_layers // per
            assert n_groups * per == cfg.n_layers
            sp["mlstm"] = stack_specs(
                stack_specs(mlstm_block_specs(cfg), per - 1), n_groups)
            sp["slstm"] = stack_specs(slstm_block_specs(cfg), n_groups)
        elif cfg.family == "zamba":
            per = cfg.zamba.shared_every
            n_groups = cfg.n_layers // per
            n_tail = cfg.n_layers - n_groups * per
            sp["mamba"] = stack_specs(
                stack_specs(mamba_block_specs(cfg), per), n_groups)
            if n_tail:
                sp["mamba_tail"] = stack_specs(mamba_block_specs(cfg), n_tail)
            sp["shared"] = zamba_shared_specs(cfg)
            sp["lora"] = stack_specs(zamba_lora_specs(cfg), n_groups)
        else:
            raise ValueError(cfg.family)
        return sp

    def n_params(self) -> int:
        return param_count(self.param_specs())

    # ==================================================================
    # embedding / head
    # ==================================================================
    def _embed(self, params: Params, inputs: Dict[str, jax.Array]
               ) -> jax.Array:
        cfg = self.cfg
        if cfg.embed_inputs:
            emb = params["embed"]
            if isinstance(emb, QTensor):
                h = dequant_rows(emb, inputs["tokens"],
                                 cfg.activation_dtype())
            else:
                h = emb[inputs["tokens"]]
        else:
            h = inputs["embeddings"].astype(cfg.activation_dtype())
        if cfg.embed_scale:
            h = h * jnp.asarray(math.sqrt(cfg.d_model), h.dtype)
        return h.astype(cfg.activation_dtype())

    def _logits(self, params: Params, h: jax.Array) -> jax.Array:
        cfg = self.cfg
        h = apply_norm(params["ln_final"], cfg, h)
        w = params["embed"] if (cfg.tie_embeddings or "head" not in params) \
            else params["head"]
        if isinstance(w, QTensor):
            # fused grouped contraction: the packed vocab table is never
            # materialized in float (the tied table groups along d — the
            # contraction axis — exactly so this works)
            from repro.kernels.ref import ref_qmatmul_fused
            logits = ref_qmatmul_fused(h, w, out_dtype=jnp.float32)
        elif cfg.tie_embeddings or "head" not in params:
            logits = jnp.einsum("bsd,vd->bsv", h, w.astype(h.dtype),
                                preferred_element_type=jnp.float32)
        else:
            logits = jnp.einsum("bsd,dv->bsv", h, w.astype(h.dtype),
                                preferred_element_type=jnp.float32)
        if cfg.final_softcap:
            logits = softcap(logits, cfg.final_softcap)
        return constrain(logits, "batch", None, "tp")

    def _local_flags(self, n: int) -> jnp.ndarray:
        cfg = self.cfg
        return jnp.array([cfg.is_local_layer(i) for i in range(n)],
                         dtype=bool)

    # ==================================================================
    # full-sequence forward (training / prefill)
    # ==================================================================
    def forward(self, params: Params, inputs: Dict[str, jax.Array],
                return_kv: bool = False):
        cfg = self.cfg
        h = self._embed(params, inputs)
        b, s = h.shape[0], h.shape[1]
        positions = jnp.arange(s, dtype=jnp.int32)
        h = constrain(h, "batch", None, "tp")

        if cfg.family in ("dense", "moe"):
            h, kv = self._forward_transformer(params, h, positions, return_kv)
        elif cfg.family == "xlstm":
            h, kv = self._forward_xlstm(params, h), None
        elif cfg.family == "zamba":
            h, kv = self._forward_zamba(params, h, positions, return_kv)
        logits = self._logits(params, h)
        return (logits, kv) if return_kv else logits

    def _maybe_remat(self, fn):
        return jax.checkpoint(fn, prevent_cse=False) if self.cfg.remat else fn

    def _forward_transformer(self, params, h, positions, return_kv):
        cfg = self.cfg
        n_first = (cfg.moe.first_dense_layers
                   if (cfg.moe and cfg.moe.first_dense_layers) else 0)
        kvs = {}

        if n_first:
            def first_body(x, layer_p):
                x, kv = transformer_block(layer_p, cfg, x, positions,
                                          jnp.bool_(False),
                                          dense_override=True)
                x = constrain(x, "batch", None, "tp")
                return x, kv if return_kv else None
            h, kv_f = scan_layers(self._maybe_remat(first_body), h,
                                  params["first_blocks"], cfg.unroll)
            if return_kv:
                kvs["attn_first"] = kv_f

        flags = self._local_flags(cfg.n_layers)[n_first:]

        def body(x, inp):
            layer_p, is_local = inp
            x, kv = transformer_block(layer_p, cfg, x, positions, is_local)
            x = constrain(x, "batch", None, "tp")
            return x, kv if return_kv else None

        h, kv_main = scan_layers(self._maybe_remat(body), h,
                                 (params["blocks"], flags), cfg.unroll)
        if return_kv:
            kvs["attn"] = kv_main
        return h, kvs

    def _forward_xlstm(self, params, h):
        cfg = self.cfg

        def group_body(x, group_p):
            mlstm_p, slstm_p = group_p

            def inner(xi, lp):
                xi = mlstm_block(lp, cfg, xi)
                return constrain(xi, "batch", None, "tp"), None

            x, _ = scan_layers(self._maybe_remat(inner), x, mlstm_p,
                               cfg.unroll)
            x = slstm_block(slstm_p, cfg, x)
            return constrain(x, "batch", None, "tp"), None

        h, _ = scan_layers(self._maybe_remat(group_body), h,
                           (params["mlstm"], params["slstm"]), cfg.unroll)
        return h

    def _forward_zamba(self, params, h, positions, return_kv):
        cfg = self.cfg
        shared = params["shared"]

        def group_body(x, group_p):
            mamba_p, lora_p = group_p

            def inner(xi, lp):
                xi = mamba_block(lp, cfg, xi)
                return constrain(xi, "batch", None, "tp"), None

            x, _ = scan_layers(self._maybe_remat(inner), x, mamba_p,
                               cfg.unroll)
            x, kv = zamba_shared_block(shared, lora_p, cfg, x, positions)
            return constrain(x, "batch", None, "tp"), \
                kv if return_kv else None

        h, kv = scan_layers(self._maybe_remat(group_body), h,
                            (params["mamba"], params["lora"]), cfg.unroll)

        if "mamba_tail" in params:
            def tail(xi, lp):
                xi = mamba_block(lp, cfg, xi)
                return constrain(xi, "batch", None, "tp"), None
            h, _ = scan_layers(self._maybe_remat(tail), h,
                               params["mamba_tail"], cfg.unroll)
        return h, ({"attn": kv} if return_kv else {})

    # ==================================================================
    # loss
    # ==================================================================
    def loss(self, params: Params, batch: Dict[str, jax.Array]) -> jax.Array:
        logits = self.forward(params, batch)
        return cross_entropy_loss(logits, batch["labels"])

    # ==================================================================
    # prefill: forward + return caches sized to the prompt
    # ==================================================================
    def prefill(self, params: Params, inputs: Dict[str, jax.Array]):
        logits, kv = self.forward(params, inputs, return_kv=True)
        return logits[:, -1:, :], kv

    # ==================================================================
    # decode
    # ==================================================================
    def decode_step(self, params: Params, cache: Any,
                    inputs: Dict[str, jax.Array], pos: jax.Array):
        """One token for every sequence in the batch.

        inputs: {tokens: (b,1)} or {embeddings: (b,1,d)}; pos: scalar int32.
        cache layout from `cache_specs`.
        """
        cfg = self.cfg
        h = self._embed(params, inputs)
        h = constrain(h, "batch", None, "tp")

        if cfg.family in ("dense", "moe"):
            h, cache = self._decode_transformer(params, h, cache, pos)
        elif cfg.family == "xlstm":
            h, cache = self._decode_xlstm(params, h, cache)
        elif cfg.family == "zamba":
            h, cache = self._decode_zamba(params, h, cache, pos)
        logits = self._logits(params, h)
        return logits, cache

    def _decode_transformer(self, params, h, cache, pos):
        cfg = self.cfg
        n_first = (cfg.moe.first_dense_layers
                   if (cfg.moe and cfg.moe.first_dense_layers) else 0)
        if n_first:
            def first_body(x, inp):
                layer_p, c = inp
                x, c = transformer_block_decode(layer_p, cfg, x, c, pos,
                                                jnp.bool_(False),
                                                dense_override=True)
                return constrain(x, "batch", None, "tp"), c
            h, cf = scan_layers(first_body, h,
                                (params["first_blocks"],
                                 cache["attn_first"]), cfg.unroll)
            cache = dict(cache, attn_first=cf)

        flags = self._local_flags(cfg.n_layers)[n_first:]

        def body(x, inp):
            layer_p, c, is_local = inp
            x, c = transformer_block_decode(layer_p, cfg, x, c, pos, is_local)
            return constrain(x, "batch", None, "tp"), c

        h, cm = scan_layers(body, h, (params["blocks"], cache["attn"],
                                      flags), cfg.unroll)
        return h, dict(cache, attn=cm)

    def _decode_xlstm(self, params, h, cache):
        cfg = self.cfg

        def group_body(x, inp):
            (mlstm_p, slstm_p), (mc, sc) = inp

            def inner(xi, lp_c):
                lp, c = lp_c
                xi, c = mlstm_block_decode(lp, cfg, xi, c)
                return constrain(xi, "batch", None, "tp"), c

            x, mc = scan_layers(inner, x, (mlstm_p, mc), cfg.unroll)
            x, sc = slstm_block_decode(slstm_p, cfg, x, sc)
            return constrain(x, "batch", None, "tp"), (mc, sc)

        h, (mc, sc) = scan_layers(
            group_body, h,
            ((params["mlstm"], params["slstm"]),
             (cache["mlstm"], cache["slstm"])), cfg.unroll)
        return h, dict(cache, mlstm=mc, slstm=sc)

    def _decode_zamba(self, params, h, cache, pos):
        cfg = self.cfg
        shared = params["shared"]

        def group_body(x, inp):
            (mamba_p, lora_p), (mc, ac) = inp

            def inner(xi, lp_c):
                lp, c = lp_c
                xi, c = mamba_block_decode(lp, cfg, xi, c)
                return constrain(xi, "batch", None, "tp"), c

            x, mc = scan_layers(inner, x, (mamba_p, mc), cfg.unroll)
            x, ac = zamba_shared_block_decode(shared, lora_p, cfg, x, ac, pos)
            return constrain(x, "batch", None, "tp"), (mc, ac)

        h, (mc, ac) = scan_layers(
            group_body, h,
            ((params["mamba"], params["lora"]),
             (cache["mamba"], cache["attn"])), cfg.unroll)
        cache = dict(cache, mamba=mc, attn=ac)

        if "mamba_tail" in params:
            def tail(xi, lp_c):
                lp, c = lp_c
                xi, c = mamba_block_decode(lp, cfg, xi, c)
                return constrain(xi, "batch", None, "tp"), c
            h, tc = scan_layers(tail, h, (params["mamba_tail"],
                                          cache["mamba_tail"]), cfg.unroll)
            cache = dict(cache, mamba_tail=tc)
        return h, cache

    # ==================================================================
    # unified decode-state serve step (the serve-v2 runtime path)
    # ==================================================================
    def supports_paged(self) -> bool:
        """True when EVERY decode-state layer is paged attention KV —
        the full paged feature set (prefix sharing, fork/COW,
        speculative decoding) applies.  Families carrying recurrent
        per-lane state (xlstm, zamba) serve through the same engine via
        `serve_step` + a `StateArena`, but those capabilities stay off:
        adopting or rolling back attention pages cannot adopt or roll
        back a recurrent state."""
        return self.cfg.family in ("dense", "moe")

    def has_recurrent_state(self) -> bool:
        """Any layer carrying constant-size per-lane recurrent state
        (conv buffers, SSM/LSTM cells) — served from a `StateArena`."""
        return self.cfg.family in ("xlstm", "zamba")

    def n_paged_layers(self) -> int:
        """Attention layers backed by paged KV pools in `serve_step`
        (zamba: one shared-block invocation per mamba group)."""
        cfg = self.cfg
        if cfg.family in ("dense", "moe"):
            return cfg.n_layers
        if cfg.family == "zamba":
            return cfg.n_layers // cfg.zamba.shared_every
        return 0

    def validate_tp(self, tp: int) -> None:
        """Raise unless every tensor-parallel hot-path dim divides
        evenly across `tp` shards.  `sanitize_pspec` would silently
        replicate a non-dividing dim instead of sharding it — correct,
        but it defeats the point of paying for tp devices, so a
        misconfigured ServeConfig(tp=...) fails loudly here with the
        offending dims named."""
        if tp <= 1:
            return
        cfg = self.cfg
        bad = []
        if cfg.n_heads % tp:
            bad.append(f"n_heads={cfg.n_heads}")
        if cfg.attn_kind != "mla" and cfg.n_kv_heads % tp:
            # MLA keeps one replicated latent pool; there is no sharded
            # KV-head group dim to divide
            bad.append(f"n_kv_heads={cfg.n_kv_heads}")
        if cfg.d_ff % tp:
            bad.append(f"d_ff={cfg.d_ff}")
        if cfg.family == "moe" and cfg.moe and cfg.moe.d_ff_expert % tp:
            bad.append(f"moe.d_ff_expert={cfg.moe.d_ff_expert}")
        if bad:
            raise ValueError(
                f"tp={tp} does not divide the tensor-parallel dims of "
                f"{cfg.name!r}: " + ", ".join(bad)
                + " (pick a tp that divides the head and FFN widths)")

    def paged_step(self, params: Params, cache: Any,
                   inputs: Dict[str, jax.Array], tables: jax.Array,
                   lengths: jax.Array, n_new: jax.Array):
        """Advance a dynamic batch against the paged KV pool.

        inputs: {tokens: (b, s)} — s == 1 is a decode step for the whole
        batch; s > 1 is a chunked BATCH PREFILL (each lane consumes
        `n_new[i] <= s` prompt tokens this call; lanes with n_new == 0
        are inactive padding).  tables: (b, max_pages) page ids per lane;
        lengths: (b,) tokens already in cache per lane.

        Returns (logits (b, s, vocab), cache); the caller samples lane i
        from logits[i, n_new[i] - 1].  Per-lane positions mean one
        lane's writes can never touch another lane's pages.

        Attention-only alias of `serve_step` (kept for the spec drafter
        and kernel tests, which are paged-KV by construction).
        """
        return self._paged_forward(params, cache, inputs, tables, lengths,
                                   n_new, verify=False)

    def serve_step(self, params: Params, cache: Any,
                   inputs: Dict[str, jax.Array], tables: jax.Array,
                   lengths: jax.Array, n_new: jax.Array):
        """Family-agnostic engine step: one call advances a dynamic
        batch for ANY family, s == 1 decode or s > 1 chunked prefill.

        `cache` is the unified per-layer decode state from
        `decode_state_specs`, flattened into one dict: paged KV page
        pools for attention layers (addressed via `tables`/`lengths`,
        exactly `paged_step`) and per-lane StateArena slots for
        recurrent layers (row i of every arena leaf's batch axis is
        lane i).  Recurrent layers derive a (b, s) validity mask from
        `n_new` — masked positions update nothing, so one lane's
        padding can never corrupt another lane's state and lanes may
        enter/leave the batch at any chunk boundary (continuous
        batching for every family).
        """
        cfg = self.cfg
        if cfg.family in ("dense", "moe"):
            return self._paged_forward(params, cache, inputs, tables,
                                       lengths, n_new, verify=False)
        h = self._embed(params, inputs)
        h = constrain(h, "batch", None, "tp")
        s = h.shape[1]
        valid = jnp.arange(s, dtype=jnp.int32)[None, :] < n_new[:, None]
        if cfg.family == "xlstm":
            h, cache = self._serve_xlstm(params, h, cache, valid)
        elif cfg.family == "zamba":
            h, cache = self._serve_zamba(params, h, cache, tables, lengths,
                                         n_new, valid)
        else:
            raise ValueError(cfg.family)
        logits = self._logits(params, h)
        return logits, cache

    def _serve_xlstm(self, params, h, cache, valid):
        cfg = self.cfg

        def group_body(x, inp):
            (mlstm_p, slstm_p), (mc, sc) = inp

            def inner(xi, lp_c):
                lp, c = lp_c
                xi, c = mlstm_block_serve(lp, cfg, xi, c, valid)
                return constrain(xi, "batch", None, "tp"), c

            x, mc = scan_layers(inner, x, (mlstm_p, mc), cfg.unroll)
            x, sc = slstm_block_serve(slstm_p, cfg, x, sc, valid)
            return constrain(x, "batch", None, "tp"), (mc, sc)

        h, (mc, sc) = scan_layers(
            group_body, h,
            ((params["mlstm"], params["slstm"]),
             (cache["mlstm"], cache["slstm"])), cfg.unroll)
        return h, dict(cache, mlstm=mc, slstm=sc)

    def _serve_zamba(self, params, h, cache, tables, lengths, n_new, valid):
        cfg = self.cfg
        shared = params["shared"]
        n_groups = self.n_paged_layers()

        if n_groups:
            def group_body(x, inp):
                (mamba_p, lora_p), (mc, ac) = inp

                def inner(xi, lp_c):
                    lp, c = lp_c
                    xi, c = mamba_block_serve(lp, cfg, xi, c, valid)
                    return constrain(xi, "batch", None, "tp"), c

                x, mc = scan_layers(inner, x, (mamba_p, mc), cfg.unroll)
                x, ac = zamba_shared_block_paged(shared, lora_p, cfg, x, ac,
                                                 tables, lengths, n_new)
                return constrain(x, "batch", None, "tp"), (mc, ac)

            h, (mc, ac) = scan_layers(
                group_body, h,
                ((params["mamba"], params["lora"]),
                 (cache["mamba"], cache["attn"])), cfg.unroll)
            cache = dict(cache, mamba=mc, attn=ac)

        if "mamba_tail" in params:
            def tail(xi, lp_c):
                lp, c = lp_c
                xi, c = mamba_block_serve(lp, cfg, xi, c, valid)
                return constrain(xi, "batch", None, "tp"), c
            h, tc = scan_layers(tail, h, (params["mamba_tail"],
                                          cache["mamba_tail"]), cfg.unroll)
            cache = dict(cache, mamba_tail=tc)
        return h, cache

    def paged_verify_step(self, params: Params, cache: Any,
                          inputs: Dict[str, jax.Array], tables: jax.Array,
                          lengths: jax.Array, n_new: jax.Array):
        """Speculative-decode verify: score a k-token draft window in one
        pass.

        inputs: {tokens: (b, s)} — lane i's row is [last_emitted,
        d_1, ..., d_{n_new[i]-1}, pad...]; `lengths` counts tokens
        already cached (the window's KV rows are written by this call,
        exactly like chunked prefill).  Returns logits (b, s, vocab):
        logits[i, j] is the target distribution for the token AFTER
        window position j — the acceptance rule walks it left to right.
        Identical math to `paged_step` (same intra-window causal mask);
        the difference is routing: attention runs the multi-query flash
        kernel instead of gathering every page the lane owns, which is
        what turns decode GEMV into small-batch GEMM.
        """
        return self._paged_forward(params, cache, inputs, tables, lengths,
                                   n_new, verify=True)

    def _paged_forward(self, params, cache, inputs, tables, lengths, n_new,
                       verify: bool):
        cfg = self.cfg
        assert self.supports_paged(), cfg.family
        h = self._embed(params, inputs)
        h = constrain(h, "batch", None, "tp")

        n_first = (cfg.moe.first_dense_layers
                   if (cfg.moe and cfg.moe.first_dense_layers) else 0)
        if n_first:
            def first_body(x, inp):
                layer_p, c = inp
                x, c = transformer_block_paged(
                    layer_p, cfg, x, c, tables, lengths, n_new,
                    jnp.bool_(False), dense_override=True, verify=verify)
                return constrain(x, "batch", None, "tp"), c
            h, cf = scan_layers(first_body, h,
                                (params["first_blocks"],
                                 cache["attn_first"]), cfg.unroll)
            cache = dict(cache, attn_first=cf)

        flags = self._local_flags(cfg.n_layers)[n_first:]

        def body(x, inp):
            layer_p, c, is_local = inp
            x, c = transformer_block_paged(layer_p, cfg, x, c, tables,
                                           lengths, n_new, is_local,
                                           verify=verify)
            return constrain(x, "batch", None, "tp"), c

        h, cm = scan_layers(body, h, (params["blocks"], cache["attn"],
                                      flags), cfg.unroll)
        logits = self._logits(params, h)
        return logits, dict(cache, attn=cm)

    # ==================================================================
    # cache specs (ParamSpec pytree: shapes + dtypes + logical axes)
    # ==================================================================
    def cache_specs(self, batch: int, max_seq: int,
                    kv_dtype=jnp.bfloat16) -> Any:
        cfg = self.cfg

        def attn_axes(struct):
            if len(struct.shape) == 4:          # (b, S, g, hd)
                return (BATCH, KV_SEQ, NONE, NONE)
            return (BATCH, KV_SEQ, NONE)        # (b, S, r) MLA latent

        def to_spec(struct, axes):
            return ParamSpec(tuple(struct.shape), struct.dtype, axes,
                             init="zeros")

        def stack(spec: ParamSpec, n: int) -> ParamSpec:
            return spec.stacked(n)

        if cfg.family in ("dense", "moe"):
            one = empty_cache_spec(cfg, batch, max_seq, kv_dtype)
            one_specs = {k: to_spec(v, attn_axes(v)) for k, v in one.items()}
            n_first = (cfg.moe.first_dense_layers
                       if (cfg.moe and cfg.moe.first_dense_layers) else 0)
            out = {"attn": {k: stack(v, cfg.n_layers - n_first)
                            for k, v in one_specs.items()}}
            if n_first:
                out["attn_first"] = {k: stack(v, n_first)
                                     for k, v in one_specs.items()}
            return out

        if cfg.family == "xlstm":
            return self.arena_state_specs(batch)

        if cfg.family == "zamba":
            n_groups = cfg.n_layers // cfg.zamba.shared_every
            a_one = {k: to_spec(v, attn_axes(v))
                     for k, v in empty_cache_spec(cfg, batch, max_seq,
                                                  kv_dtype).items()}
            out = dict(self.arena_state_specs(batch))
            out["attn"] = {k: stack(v, n_groups) for k, v in a_one.items()}
            if "mamba" not in out:      # pure-mamba: zero-group stack so
                mb_axes = {"state": (BATCH, TP, NONE, NONE),   # decode_step
                           "conv": (BATCH, NONE, TP)}          # still scans
                out["mamba"] = {
                    k: stack(stack(to_spec(v, mb_axes[k]),
                                   cfg.zamba.shared_every), 0)
                    for k, v in mamba2_cache_spec(cfg, batch).items()}
            return out

        raise ValueError(cfg.family)

    def arena_state_specs(self, batch: int) -> Any:
        """ParamSpec pytree of the RECURRENT per-lane decode state for a
        `batch`-lane StateArena ({} for attention-only families).  Row i
        of every leaf's `BATCH` axis is lane i — the serve engine
        gathers/scatters that axis for lane reset, host save/restore on
        preemption, and admission into a running batch."""
        cfg = self.cfg

        def to_spec(struct, axes):
            # conv ring buffers hold raw activation projections; the
            # serve cells carry them at the promoted dtype (a scan carry
            # is dtype-stable), so the arena starts there — zeros
            # promote exactly, and the engine's jitted step never
            # retraces on a dtype flip
            dt = jnp.promote_types(struct.dtype, cfg.activation_dtype())
            return ParamSpec(tuple(struct.shape), dt, axes, init="zeros")

        if cfg.family == "xlstm":
            per = cfg.ssm.slstm_every
            n_groups = cfg.n_layers // per
            m_axes = {"C": (BATCH, NONE, TP, NONE), "n": (BATCH, NONE, TP),
                      "m": (BATCH, NONE), "conv": (BATCH, NONE, TP)}
            s_axes = {"c": (BATCH, TP), "n": (BATCH, TP), "h": (BATCH, TP),
                      "m": (BATCH, NONE)}
            m_one = {k: to_spec(v, m_axes[k])
                     for k, v in mlstm_cache_spec(cfg, batch).items()}
            s_one = {k: to_spec(v, s_axes[k])
                     for k, v in slstm_cache_spec(cfg, batch).items()}
            return {
                "mlstm": {k: v.stacked(per - 1).stacked(n_groups)
                          for k, v in m_one.items()},
                "slstm": {k: v.stacked(n_groups)
                          for k, v in s_one.items()},
            }

        if cfg.family == "zamba":
            per = cfg.zamba.shared_every
            n_groups = cfg.n_layers // per
            n_tail = cfg.n_layers - n_groups * per
            mb_axes = {"state": (BATCH, TP, NONE, NONE),
                       "conv": (BATCH, NONE, TP)}
            m_one = {k: to_spec(v, mb_axes[k])
                     for k, v in mamba2_cache_spec(cfg, batch).items()}
            out = {}
            if n_groups:
                out["mamba"] = {k: v.stacked(per).stacked(n_groups)
                                for k, v in m_one.items()}
            if n_tail:
                out["mamba_tail"] = {k: v.stacked(n_tail)
                                     for k, v in m_one.items()}
            return out

        return {}

    def paged_cache_specs(self, n_pages: int, page_size: int,
                          kv_dtype=jnp.bfloat16) -> Any:
        """ParamSpec pytree for the paged KV pool: per-layer page pools
        stacked over layers (scan layout), shared by every sequence via
        block tables.  Total KV memory is n_pages * page_size rows —
        sized to the WORKLOAD, not to n_slots * max_seq.  Families
        without attention layers (xlstm, pure-mamba zamba) return {} —
        their whole decode state lives in the StateArena instead."""
        cfg = self.cfg
        n_attn = self.n_paged_layers()
        if n_attn == 0:
            return {}

        def pool_axes(name, struct):
            if len(struct.shape) == 4:          # (n_pages, ps, g, hd)
                return (NONE, NONE, TP, NONE)
            if name.endswith("_scale"):         # (n_pages, ps, g) INT8 scales
                return (NONE, NONE, TP)
            return (NONE, NONE, NONE)           # (n_pages, ps, r) MLA latent

        pool_cfg = cfg
        if cfg.family == "zamba":               # shared attn block's shape
            pool_cfg = cfg.replace(d_ff=cfg.zamba.shared_d_ff, moe=None)
        one = paged_cache_spec(pool_cfg, n_pages, page_size, kv_dtype)
        one_specs = {k: ParamSpec(tuple(v.shape), v.dtype, pool_axes(k, v),
                                  init="zeros") for k, v in one.items()}
        n_first = (cfg.moe.first_dense_layers
                   if (cfg.moe and cfg.moe.first_dense_layers) else 0)
        if cfg.family == "zamba":
            n_first = 0
        out = {"attn": {k: v.stacked(n_attn - n_first)
                        for k, v in one_specs.items()}}
        if n_first:
            out["attn_first"] = {k: v.stacked(n_first)
                                 for k, v in one_specs.items()}
        return out

    def decode_state_specs(self, max_batch: int, n_pages: int,
                           page_size: int, kv_dtype=jnp.bfloat16) -> Any:
        """Unified per-layer decode state for the serve runtime,
        generalizing `paged_cache_specs`:

          {"paged": per-layer KV page pools (attention layers; {} when
                    the family has none),
           "arena": per-lane recurrent-state slots, batch = max_batch
                    ({} for attention-only families)}

        The engine materializes both, flattens them into one cache dict
        for `serve_step`, and owns the host-side bookkeeping (block
        tables for "paged", lane reset/save/restore for "arena")."""
        return {"paged": self.paged_cache_specs(n_pages, page_size,
                                                kv_dtype),
                "arena": self.arena_state_specs(max_batch)}
