"""Block assembly for every architecture family + scanned stacks.

Families (DESIGN.md SS4):
  dense / moe : [pre-norm attn, pre-norm FFN] x L, optional gemma-style
                post-block norms, local/global flags scanned per layer.
  xlstm       : groups of (slstm_every-1) mLSTM blocks + 1 sLSTM block.
  zamba       : groups of `shared_every` Mamba2 blocks + one invocation of
                a SHARED attention+MLP block with per-site LoRA deltas,
                plus trailing Mamba2 layers.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import (attention_specs, attn_decode, attn_forward,
                        attn_paged_step)
from .common import FSDP, NONE, TP, ParamSpec, layer_norm, rms_norm
from .config import ModelConfig
from .ffn import dense_ffn, dense_ffn_specs, ffn_forward, ffn_specs
from .ssm import (mamba2_decode, mamba2_forward, mamba2_serve_step,
                  mamba2_specs, mlstm_decode, mlstm_forward,
                  mlstm_serve_step, mlstm_specs, slstm_decode,
                  slstm_forward, slstm_serve_step, slstm_specs)

Params = Dict[str, Any]


# ----------------------------------------------------------------------------
# norms
# ----------------------------------------------------------------------------
def norm_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    init = "zeros" if cfg.rms_scale_plus_one else "ones"
    sp = {"scale": ParamSpec((d,), axes=(NONE,), init=init)}
    if cfg.norm_kind == "layer":
        sp["bias"] = ParamSpec((d,), axes=(NONE,), init="zeros")
    return sp


def apply_norm(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.norm_kind == "layer":
        return layer_norm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rms_norm(x, p["scale"], cfg.norm_eps,
                    scale_plus_one=cfg.rms_scale_plus_one)


# ----------------------------------------------------------------------------
# transformer block (dense / moe)
# ----------------------------------------------------------------------------
def transformer_block_specs(cfg: ModelConfig, dense_ffn_override: int = 0
                            ) -> Dict[str, Any]:
    sp: Dict[str, Any] = {
        "ln_attn": norm_specs(cfg),
        "attn": attention_specs(cfg),
        "ln_ffn": norm_specs(cfg),
        "ffn": (dense_ffn_specs(cfg, dense_ffn_override)
                if dense_ffn_override else ffn_specs(cfg)),
    }
    if cfg.post_block_norm:
        sp["post_attn"] = norm_specs(cfg)
        sp["post_ffn"] = norm_specs(cfg)
    return sp


def transformer_block(p: Params, cfg: ModelConfig, x: jax.Array,
                      positions: jax.Array, is_local,
                      dense_override: bool = False
                      ) -> Tuple[jax.Array, Dict]:
    h = apply_norm(p["ln_attn"], cfg, x)
    a, kv = attn_forward(p["attn"], cfg, h, positions, is_local)
    if cfg.post_block_norm:
        a = apply_norm(p["post_attn"], cfg, a)
    x = x + a
    h = apply_norm(p["ln_ffn"], cfg, x)
    f = dense_ffn(p["ffn"], cfg, h) if dense_override \
        else ffn_forward(p["ffn"], cfg, h)
    if cfg.post_block_norm:
        f = apply_norm(p["post_ffn"], cfg, f)
    return x + f, kv


def transformer_block_decode(p: Params, cfg: ModelConfig, x: jax.Array,
                             cache: Dict, pos, is_local,
                             dense_override: bool = False
                             ) -> Tuple[jax.Array, Dict]:
    h = apply_norm(p["ln_attn"], cfg, x)
    a, cache = attn_decode(p["attn"], cfg, h, cache, pos, is_local)
    if cfg.post_block_norm:
        a = apply_norm(p["post_attn"], cfg, a)
    x = x + a
    h = apply_norm(p["ln_ffn"], cfg, x)
    f = dense_ffn(p["ffn"], cfg, h) if dense_override \
        else ffn_forward(p["ffn"], cfg, h)
    if cfg.post_block_norm:
        f = apply_norm(p["post_ffn"], cfg, f)
    return x + f, cache


def transformer_block_paged(p: Params, cfg: ModelConfig, x: jax.Array,
                            cache: Dict, tables: jax.Array,
                            lengths: jax.Array, n_new: jax.Array, is_local,
                            dense_override: bool = False,
                            verify: bool = False
                            ) -> Tuple[jax.Array, Dict]:
    """Decode/chunked-prefill block against a paged KV pool (x: (b,s,d))."""
    h = apply_norm(p["ln_attn"], cfg, x)
    a, cache = attn_paged_step(p["attn"], cfg, h, cache, tables, lengths,
                               n_new, is_local, verify=verify)
    if cfg.post_block_norm:
        a = apply_norm(p["post_attn"], cfg, a)
    x = x + a
    h = apply_norm(p["ln_ffn"], cfg, x)
    f = dense_ffn(p["ffn"], cfg, h) if dense_override \
        else ffn_forward(p["ffn"], cfg, h)
    if cfg.post_block_norm:
        f = apply_norm(p["post_ffn"], cfg, f)
    return x + f, cache


# ----------------------------------------------------------------------------
# xLSTM blocks
# ----------------------------------------------------------------------------
def mlstm_block_specs(cfg: ModelConfig) -> Dict[str, Any]:
    return {"ln": norm_specs(cfg), "cell": mlstm_specs(cfg)}


def slstm_block_specs(cfg: ModelConfig) -> Dict[str, Any]:
    return {"ln": norm_specs(cfg), "cell": slstm_specs(cfg)}


def mlstm_block(p, cfg, x):
    return x + mlstm_forward(p["cell"], cfg, apply_norm(p["ln"], cfg, x))


def slstm_block(p, cfg, x):
    return x + slstm_forward(p["cell"], cfg, apply_norm(p["ln"], cfg, x))


def mlstm_block_decode(p, cfg, x, cache):
    out, cache = mlstm_decode(p["cell"], cfg, apply_norm(p["ln"], cfg, x),
                              cache)
    return x + out, cache


def slstm_block_decode(p, cfg, x, cache):
    out, cache = slstm_decode(p["cell"], cfg, apply_norm(p["ln"], cfg, x),
                              cache)
    return x + out, cache


def mlstm_block_serve(p, cfg, x, cache, valid):
    out, cache = mlstm_serve_step(p["cell"], cfg,
                                  apply_norm(p["ln"], cfg, x), cache, valid)
    return x + out, cache


def slstm_block_serve(p, cfg, x, cache, valid):
    out, cache = slstm_serve_step(p["cell"], cfg,
                                  apply_norm(p["ln"], cfg, x), cache, valid)
    return x + out, cache


# ----------------------------------------------------------------------------
# mamba block + zamba shared attention block
# ----------------------------------------------------------------------------
def mamba_block_specs(cfg: ModelConfig) -> Dict[str, Any]:
    return {"ln": norm_specs(cfg), "cell": mamba2_specs(cfg)}


def mamba_block(p, cfg, x):
    return x + mamba2_forward(p["cell"], cfg, apply_norm(p["ln"], cfg, x))


def mamba_block_decode(p, cfg, x, cache):
    out, cache = mamba2_decode(p["cell"], cfg, apply_norm(p["ln"], cfg, x),
                               cache)
    return x + out, cache


def mamba_block_serve(p, cfg, x, cache, valid):
    out, cache = mamba2_serve_step(p["cell"], cfg,
                                   apply_norm(p["ln"], cfg, x), cache, valid)
    return x + out, cache


def zamba_shared_specs(cfg: ModelConfig) -> Dict[str, Any]:
    """The SHARED attention+MLP block (one copy for the whole model)."""
    z = cfg.zamba
    shared_cfg = cfg.replace(d_ff=z.shared_d_ff, moe=None)
    return {
        "ln_attn": norm_specs(cfg),
        "attn": attention_specs(shared_cfg),
        "ln_ffn": norm_specs(cfg),
        "ffn": dense_ffn_specs(shared_cfg),
    }


def zamba_lora_specs(cfg: ModelConfig) -> Dict[str, Any]:
    """Per-invocation LoRA deltas on q/k/v + output gate projection."""
    z = cfg.zamba
    d, hd = cfg.d_model, cfg.hd()
    r = z.lora_rank
    sp = {}
    for nm, out_dim in (("q", cfg.n_heads * hd), ("k", cfg.n_kv_heads * hd),
                        ("v", cfg.n_kv_heads * hd)):
        sp[f"lora_a_{nm}"] = ParamSpec((d, r), axes=(FSDP, NONE))
        sp[f"lora_b_{nm}"] = ParamSpec((r, out_dim), axes=(NONE, TP),
                                       init="zeros")
    sp["out_proj"] = ParamSpec((d, d), axes=(FSDP, NONE))
    return sp


def _zamba_attn_params(shared: Params, lora: Params) -> Params:
    """Materialize per-site attention weights = shared + LoRA delta."""
    from repro.quant.qarray import maybe_dequantize as _deq
    p = dict(shared["attn"])
    for nm, key in (("q", "wq"), ("k", "wk"), ("v", "wv")):
        delta = lora[f"lora_a_{nm}"] @ lora[f"lora_b_{nm}"]
        base = _deq(p[key])
        p[key] = base + delta.astype(base.dtype)
    return p


def zamba_shared_block(shared: Params, lora: Params, cfg: ModelConfig,
                       x: jax.Array, positions: jax.Array
                       ) -> Tuple[jax.Array, Dict]:
    z = cfg.zamba
    shared_cfg = cfg.replace(d_ff=z.shared_d_ff, moe=None)
    attn_p = _zamba_attn_params(shared, lora)
    h = apply_norm(shared["ln_attn"], cfg, x)
    a, kv = attn_forward(attn_p, shared_cfg, h, positions, jnp.bool_(False))
    from repro.kernels.ops import qmatmul_xla as _qmm
    x = x + _qmm(a, lora["out_proj"])
    h = apply_norm(shared["ln_ffn"], cfg, x)
    f = dense_ffn(shared["ffn"], shared_cfg, h)
    return x + f, kv


def zamba_shared_block_paged(shared: Params, lora: Params, cfg: ModelConfig,
                             x: jax.Array, cache: Dict, tables: jax.Array,
                             lengths: jax.Array, n_new: jax.Array
                             ) -> Tuple[jax.Array, Dict]:
    """Shared attn+MLP invocation against a paged KV pool (the hybrid
    family's attention layers in the serve runtime): per-lane positions
    from `lengths`, chunked-prefill masking from `n_new` — exactly the
    `transformer_block_paged` contract, with zamba's LoRA-merged weights
    and gated output projection."""
    z = cfg.zamba
    shared_cfg = cfg.replace(d_ff=z.shared_d_ff, moe=None)
    attn_p = _zamba_attn_params(shared, lora)
    h = apply_norm(shared["ln_attn"], cfg, x)
    a, cache = attn_paged_step(attn_p, shared_cfg, h, cache, tables,
                               lengths, n_new, jnp.bool_(False))
    from repro.kernels.ops import qmatmul_xla as _qmm
    x = x + _qmm(a, lora["out_proj"])
    h = apply_norm(shared["ln_ffn"], cfg, x)
    f = dense_ffn(shared["ffn"], shared_cfg, h)
    return x + f, cache


def zamba_shared_block_decode(shared: Params, lora: Params, cfg: ModelConfig,
                              x: jax.Array, cache: Dict, pos
                              ) -> Tuple[jax.Array, Dict]:
    z = cfg.zamba
    shared_cfg = cfg.replace(d_ff=z.shared_d_ff, moe=None)
    attn_p = _zamba_attn_params(shared, lora)
    h = apply_norm(shared["ln_attn"], cfg, x)
    a, cache = attn_decode(attn_p, shared_cfg, h, cache, pos,
                           jnp.bool_(False))
    from repro.kernels.ops import qmatmul_xla as _qmm
    x = x + _qmm(a, lora["out_proj"])
    h = apply_norm(shared["ln_ffn"], cfg, x)
    f = dense_ffn(shared["ffn"], shared_cfg, h)
    return x + f, cache
