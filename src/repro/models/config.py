"""Unified model configuration covering all assigned architecture families."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    q_lora_rank: int = 0          # 0 = no query compression (V2-Lite)


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 64           # routed experts
    top_k: int = 6
    n_shared_experts: int = 2
    d_ff_expert: int = 1408
    capacity_factor: float = 1.25
    router_dtype: str = "float32"
    dispatch: str = "gather"      # gather | onehot (ablation / perf study)
    first_dense_layers: int = 0   # deepseek: layer 0 is dense FFN
    first_dense_d_ff: int = 0


@dataclass(frozen=True)
class SSMConfig:
    # mamba2
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64            # mamba2 head dim (d_inner / n_heads)
    chunk: int = 256
    # xlstm
    mlstm_heads: int = 4
    slstm_every: int = 8          # 7:1 mLSTM:sLSTM -> one sLSTM per 8 layers
    time_chunk: int = 64          # remat granularity of the time scan
                                  # (SSPerf cell a: bwd saves chunk
                                  # boundaries, not every step)
    proj_factor_mlstm: float = 2.0
    proj_factor_slstm: float = 4.0 / 3.0
    conv_width: int = 4


@dataclass(frozen=True)
class ZambaConfig:
    shared_every: int = 6         # shared attn+MLP invoked every 6 mamba layers
    lora_rank: int = 64
    shared_d_ff: int = 14336


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | xlstm | zamba
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None

    # attention flavor
    attn_kind: str = "gqa"        # gqa | mla
    qkv_bias: bool = False
    qk_norm: bool = False
    attn_softcap: float = 0.0     # gemma2: 50.0
    final_softcap: float = 0.0    # gemma2: 30.0
    local_window: int = 0         # sliding-window size for local layers
    local_pattern: int = 0        # N => pattern of N layers has 1 global
                                  # (gemma2: 2 -> 1:1; gemma3: 6 -> 5:1)
    rope_theta: float = 10000.0
    rope_theta_local: float = 0.0  # gemma3 uses 10k local / 1M global

    # ffn flavor
    ffn_act: str = "silu"          # silu | gelu_tanh | gelu
    ffn_gated: bool = True

    # norm flavor
    norm_kind: str = "rms"         # rms | layer
    post_block_norm: bool = False  # gemma2/3: extra norms after attn/ffn
    rms_scale_plus_one: bool = False  # gemma (1+w) convention
    norm_eps: float = 1e-6

    # embedding / head
    tie_embeddings: bool = True
    embed_scale: bool = False      # gemma: x *= sqrt(d_model)
    embed_inputs: bool = True      # False => frontend stub provides embeddings
    logit_dtype: str = "float32"

    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    zamba: Optional[ZambaConfig] = None

    # training numerics
    dtype: str = "bfloat16"
    remat: bool = True
    # unroll scans into straight-line HLO (roofline probes: XLA cost
    # analysis counts a while-loop body ONCE, so probes unroll)
    unroll: bool = False
    # Mamba2 SSD chunk scans stay scanned even in probes (unrolling 16+
    # heavy einsum bodies explodes SPMD-partitioner time); their cost is
    # closed-form corrected in launch/probe.py instead
    unroll_ssm_chunks: bool = False

    # --------------------------------------------------------------
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    def activation_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    def is_local_layer(self, i: int) -> bool:
        """gemma-style alternation: in each `local_pattern` block, the LAST
        layer is global, the rest local."""
        if not self.local_window or not self.local_pattern:
            return False
        return (i % self.local_pattern) != (self.local_pattern - 1)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)
