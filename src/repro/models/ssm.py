"""Recurrent-state blocks: Mamba2 (chunked SSD), mLSTM, sLSTM (xLSTM).

These replace attention for the `xlstm` and `zamba` families.  Decode is
O(1)/token against a fixed-size recurrent state — which is why the
assigned long_500k shape runs for these archs (DESIGN.md SS4).

Mamba2 training uses the chunked SSD formulation (matmul-friendly: intra-
chunk attention-like block + inter-chunk state recurrence via lax.scan).
mLSTM/sLSTM train via a stabilized lax.scan over time — the paper-faithful
recurrent form (xLSTM exponential gating with max-stabilizer).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .common import FSDP, NONE, TP, ParamSpec, rms_norm, scan_layers
from repro.kernels.ops import qmatmul_xla as qmm
from repro.quant.qarray import maybe_dequantize as deq
from .config import ModelConfig

Params = Dict[str, jax.Array]


# ============================================================================
# Mamba2
# ============================================================================
def mamba2_dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = int(s.expand * cfg.d_model)
    n_heads = d_inner // s.head_dim
    return d_inner, n_heads, s.d_state


def mamba2_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    s = cfg.ssm
    d = cfg.d_model
    di, nh, ds = mamba2_dims(cfg)
    conv_dim = di + 2 * ds                       # x + B + C (single group)
    return {
        "in_proj": ParamSpec((d, 2 * di + 2 * ds + nh), axes=(FSDP, TP)),
        "conv_w": ParamSpec((s.d_conv, conv_dim), axes=(NONE, TP),
                            scale=1.0 / math.sqrt(s.d_conv)),
        "conv_b": ParamSpec((conv_dim,), axes=(TP,), init="zeros"),
        "a_log": ParamSpec((nh,), axes=(NONE,), init="zeros"),
        "d_skip": ParamSpec((nh,), axes=(NONE,), init="ones"),
        "dt_bias": ParamSpec((nh,), axes=(NONE,), init="zeros"),
        "norm": ParamSpec((di,), axes=(TP,), init="ones"),
        "out_proj": ParamSpec((di, d), axes=(TP, FSDP)),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (b,s,c), w: (k,c)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(k))
    return out + b


def _split_xbcdt(cfg: ModelConfig, proj: jax.Array):
    di, nh, ds = mamba2_dims(cfg)
    z = proj[..., :di]
    xbc = proj[..., di:di + di + 2 * ds]
    dt = proj[..., di + di + 2 * ds:]
    return z, xbc, dt


def mamba2_forward(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Chunked SSD over the full sequence. x: (b,s,d)."""
    s_cfg = cfg.ssm
    b, s_orig, _ = x.shape
    di, nh, ds = mamba2_dims(cfg)
    hd = s_cfg.head_dim
    L = min(s_cfg.chunk, s_orig)
    pad = (-s_orig) % L
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    s = s_orig + pad
    nc = s // L

    proj = qmm(x, p["in_proj"])
    z, xbc, dt_raw = _split_xbcdt(cfg, proj)
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
    xs = xbc[..., :di].reshape(b, s, nh, hd)
    B = xbc[..., di:di + ds]                                 # (b,s,n)
    C = xbc[..., di + ds:]                                   # (b,s,n)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # (b,s,h)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))              # (h,)
    dtA = dt * A[None, None, :]                               # (b,s,h)

    # chunk
    xs_c = xs.reshape(b, nc, L, nh, hd)
    B_c = B.reshape(b, nc, L, ds)
    C_c = C.reshape(b, nc, L, ds)
    dt_c = dt.reshape(b, nc, L, nh)
    dtA_c = dtA.reshape(b, nc, L, nh)

    cs = jnp.cumsum(dtA_c, axis=2)                            # (b,c,l,h)
    tot = cs[:, :, -1, :]                                     # (b,c,h)

    # put chunk dim first for the scan
    def per_chunk(carry, inp):
        state = carry                                          # (b,h,hd,n) f32
        xs_i, B_i, C_i, dt_i, cs_i, tot_i = inp
        # intra-chunk: scores_ij = (C_i . B_j) exp(cs_i - cs_j) dt_j, j <= i
        cb = jnp.einsum("bln,bmn->blm", C_i, B_i,
                        preferred_element_type=jnp.float32)    # (b,l,l)
        seg = cs_i[:, :, None, :] - cs_i[:, None, :, :]        # (b,l,m,h)
        mask = jnp.tril(jnp.ones((L, L), bool))
        decay = jnp.where(mask[None, :, :, None], jnp.exp(seg), 0.0)
        w = cb[..., None] * decay * dt_i[:, None, :, :]        # (b,l,m,h)
        y_intra = jnp.einsum("blmh,bmhp->blhp", w.astype(xs_i.dtype), xs_i)
        # inter-chunk: contribution of the carried state
        cexp = jnp.exp(cs_i)                                   # (b,l,h)
        y_inter = jnp.einsum("bln,bhpn,blh->blhp", C_i,
                             state.astype(C_i.dtype),
                             cexp.astype(C_i.dtype))
        # new chunk state
        dec_end = jnp.exp(tot_i[:, None, :] - cs_i)            # (b,l,h)
        contrib = jnp.einsum("blh,blhp,bln->bhpn",
                             (dec_end * dt_i).astype(xs_i.dtype), xs_i, B_i)
        state = state * jnp.exp(tot_i)[:, :, None, None] + \
            contrib.astype(jnp.float32)
        return state, y_intra + y_inter

    state0 = jnp.zeros((b, nh, hd, ds), jnp.float32)
    inputs = (xs_c.swapaxes(0, 1), B_c.swapaxes(0, 1), C_c.swapaxes(0, 1),
              dt_c.swapaxes(0, 1), cs.swapaxes(0, 1), tot.swapaxes(0, 1))
    _, ys = scan_layers(per_chunk, state0, inputs,
                        cfg.unroll and cfg.unroll_ssm_chunks)
    y = ys.swapaxes(0, 1).reshape(b, s, nh, hd)

    y = y + xs * p["d_skip"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(b, s, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = qmm(y, p["out_proj"])
    return out[:, :s_orig] if pad else out


def mamba2_decode(p: Params, cfg: ModelConfig, x: jax.Array, cache: Dict,
                  ) -> Tuple[jax.Array, Dict]:
    """One-step recurrence. x: (b,1,d).
    cache: {state: (b,h,hd,n) f32, conv: (b, k-1, conv_dim)}."""
    s_cfg = cfg.ssm
    b = x.shape[0]
    di, nh, ds = mamba2_dims(cfg)
    hd = s_cfg.head_dim
    k = s_cfg.d_conv

    proj = qmm(x, p["in_proj"])
    z, xbc, dt_raw = _split_xbcdt(cfg, proj)

    conv_buf = jnp.concatenate([cache["conv"], xbc], axis=1)   # (b,k,cd)
    xbc1 = jnp.einsum("bkc,kc->bc", conv_buf, p["conv_w"]) + p["conv_b"]
    xbc1 = jax.nn.silu(xbc1)[:, None, :]
    new_conv = conv_buf[:, 1:, :]

    xs = xbc1[..., :di].reshape(b, nh, hd)
    B = xbc1[..., di:di + ds][:, 0]                            # (b,n)
    C = xbc1[..., di + ds:][:, 0]                              # (b,n)

    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))   # (b,h)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    dA = jnp.exp(dt * A[None, :])                              # (b,h)

    state = cache["state"] * dA[:, :, None, None] + \
        jnp.einsum("bh,bhp,bn->bhpn", dt, xs.astype(jnp.float32),
                   B.astype(jnp.float32))
    y = jnp.einsum("bhpn,bn->bhp", state, C.astype(jnp.float32))
    y = y.astype(x.dtype) + xs * p["d_skip"].astype(x.dtype)[None, :, None]
    y = y.reshape(b, 1, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return qmm(y, p["out_proj"]), {"state": state, "conv": new_conv}


def mamba2_serve_step(p: Params, cfg: ModelConfig, x: jax.Array,
                      cache: Dict, valid: jax.Array
                      ) -> Tuple[jax.Array, Dict]:
    """Masked multi-token recurrence: up to `s` tokens per lane in ONE
    device call (chunked recurrent prefill, or s == 1 batched decode).

    x: (b, s, d); valid: (b, s) bool.  Lane i consumes its True
    positions in order; state/conv updates at masked positions are
    dropped, so a lane's final state equals the state after feeding its
    valid tokens one at a time through `mamba2_decode` — the serving
    engine's continuous-batching invariant (a padding token can never
    corrupt a shorter lane's state, which is what forced the old slot
    loop to group equal-length prompts).  Projections in and out of the
    recurrence are batched over (b, s); only the O(1)-per-token state
    update runs under the scan.
    """
    s_cfg = cfg.ssm
    b, s, _ = x.shape
    di, nh, ds = mamba2_dims(cfg)
    hd = s_cfg.head_dim

    proj = qmm(x, p["in_proj"])                               # (b,s,...)
    z, xbc, dt_raw = _split_xbcdt(cfg, proj)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # (b,s,h)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))              # (h,)

    def step(carry, inp):
        state, conv = carry
        xbc_t, dt_t, v_t = inp             # (b,cd), (b,h), (b,)
        conv_buf = jnp.concatenate([conv, xbc_t[:, None, :]], axis=1)
        xc = jnp.einsum("bkc,kc->bc", conv_buf, p["conv_w"]) + p["conv_b"]
        xc = jax.nn.silu(xc)
        xs = xc[:, :di].reshape(b, nh, hd)
        B = xc[:, di:di + ds]
        C = xc[:, di + ds:]
        dA = jnp.exp(dt_t * A[None, :])
        new_state = state * dA[:, :, None, None] + \
            jnp.einsum("bh,bhp,bn->bhpn", dt_t, xs.astype(jnp.float32),
                       B.astype(jnp.float32))
        y = jnp.einsum("bhpn,bn->bhp", new_state, C.astype(jnp.float32))
        y = y.astype(x.dtype) + xs * p["d_skip"].astype(x.dtype)[None, :,
                                                                 None]
        state = jnp.where(v_t[:, None, None, None], new_state, state)
        conv = jnp.where(v_t[:, None, None], conv_buf[:, 1:, :], conv)
        return (state, conv), y

    # the conv ring buffer stores raw projections: promote it to their
    # dtype up front — a lax.scan carry must be dtype-stable, unlike the
    # eager `mamba2_decode` path (zeros promote exactly, so a cache
    # initialized at either dtype decodes identically)
    conv0 = cache["conv"].astype(jnp.promote_types(cache["conv"].dtype,
                                                   xbc.dtype))
    (state, conv), ys = jax.lax.scan(
        step, (cache["state"], conv0),
        (xbc.swapaxes(0, 1), dt.swapaxes(0, 1), valid.swapaxes(0, 1)))
    y = ys.swapaxes(0, 1).reshape(b, s, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return qmm(y, p["out_proj"]), {"state": state, "conv": conv}


def mamba2_cache_spec(cfg: ModelConfig, batch: int):
    di, nh, ds = mamba2_dims(cfg)
    cd = di + 2 * ds
    return {
        "state": jax.ShapeDtypeStruct((batch, nh, cfg.ssm.head_dim, ds),
                                      jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, cfg.ssm.d_conv - 1, cd),
                                     jnp.bfloat16),
    }


# ============================================================================
# mLSTM (xLSTM matrix-memory block)
# ============================================================================
def mlstm_dims(cfg: ModelConfig):
    s = cfg.ssm
    di = int(s.proj_factor_mlstm * cfg.d_model)
    nh = s.mlstm_heads
    return di, nh, di // nh


def mlstm_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    s = cfg.ssm
    d = cfg.d_model
    di, nh, dh = mlstm_dims(cfg)
    return {
        "up_proj": ParamSpec((d, 2 * di), axes=(FSDP, TP)),
        "conv_w": ParamSpec((s.conv_width, di), axes=(NONE, TP),
                            scale=1.0 / math.sqrt(s.conv_width)),
        "conv_b": ParamSpec((di,), axes=(TP,), init="zeros"),
        # headwise (block-diagonal) q/k/v: (h, dh, dh).  Sharded on the
        # OUTPUT dh dim: nh(=4) cannot shard over a 16-way model axis
        # (SSPerf cell a: replicated qkv made the scan carry unsharded)
        "wq": ParamSpec((nh, dh, dh), axes=(NONE, NONE, TP)),
        "wk": ParamSpec((nh, dh, dh), axes=(NONE, NONE, TP)),
        "wv": ParamSpec((nh, dh, dh), axes=(NONE, NONE, TP)),
        "w_if": ParamSpec((di, 2 * nh), axes=(FSDP, NONE),
                          scale=1.0 / math.sqrt(di)),
        "b_if": ParamSpec((2 * nh,), axes=(NONE,), init="zeros"),
        "w_o": ParamSpec((di, di), axes=(FSDP, TP)),
        "hnorm": ParamSpec((di,), axes=(TP,), init="ones"),
        "down_proj": ParamSpec((di, d), axes=(TP, FSDP)),
    }


def _mlstm_cell(q, k, v, i_raw, f_raw, state):
    """One step. q/k/v: (b,h,dh); i/f: (b,h); state {C,n,m}.

    The carry sharding is pinned (batch x dh_v over data x model): without
    the constraint SPMD flip-flops the loop state to replicated
    ("involuntary full rematerialization"), blowing the 4096-step backward
    to >200 GiB/device (SSPerf cell a3)."""
    from repro.dist.shard import constrain
    C, n, m = state
    log_f = -jax.nn.softplus(-f_raw)                    # log sigmoid(f)
    m_new = jnp.maximum(log_f + m, i_raw)
    i_p = jnp.exp(i_raw - m_new)
    f_p = jnp.exp(log_f + m - m_new)
    C = f_p[..., None, None] * C + \
        i_p[..., None, None] * jnp.einsum("bhv,bhk->bhvk", v, k)
    C = constrain(C, "batch", None, "tp", None)
    n = f_p[..., None] * n + i_p[..., None] * k
    n = constrain(n, "batch", None, "tp")
    num = jnp.einsum("bhvk,bhk->bhv", C, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q)),
                      jnp.exp(-m_new))
    h = num / den[..., None]
    return (C, n, m_new), h


def _mlstm_qkvif(p: Params, cfg: ModelConfig, x_m: jax.Array):
    di, nh, dh = mlstm_dims(cfg)
    lead = x_m.shape[:-1]
    xh = x_m.reshape(*lead, nh, dh)
    q = jnp.einsum("...hd,hde->...he", xh, deq(p["wq"]).astype(xh.dtype))
    k = jnp.einsum("...hd,hde->...he", xh,
                   deq(p["wk"]).astype(xh.dtype)) / math.sqrt(dh)
    v = jnp.einsum("...hd,hde->...he", xh, deq(p["wv"]).astype(xh.dtype))
    gates = (x_m @ p["w_if"] + p["b_if"]).astype(jnp.float32)
    i_raw, f_raw = gates[..., :nh], gates[..., nh:]
    return q.astype(jnp.float32), k.astype(jnp.float32), \
        v.astype(jnp.float32), i_raw, f_raw


def mlstm_forward(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    b, s, _ = x.shape
    di, nh, dh = mlstm_dims(cfg)
    up = qmm(x, p["up_proj"])
    x_m, z = up[..., :di], up[..., di:]
    x_c = jax.nn.silu(_causal_conv(x_m, p["conv_w"], p["conv_b"]))
    q, k, v, i_raw, f_raw = _mlstm_qkvif(p, cfg, x_c)
    o = jax.nn.sigmoid(qmm(x_m, p["w_o"]))

    def step(state, inp):
        qt, kt, vt, it, ft = inp
        state, h = _mlstm_cell(qt, kt, vt, it, ft, state)
        return state, h

    state0 = (jnp.zeros((b, nh, dh, dh), jnp.float32),
              jnp.zeros((b, nh, dh), jnp.float32),
              jnp.full((b, nh), -1e30, jnp.float32))
    inputs = (q.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
              i_raw.swapaxes(0, 1), f_raw.swapaxes(0, 1))
    _, hs = jax.lax.scan(step, state0, inputs)
    h = hs.swapaxes(0, 1).reshape(b, s, di).astype(x.dtype)
    h = rms_norm(h, p["hnorm"], cfg.norm_eps) * o
    out = h * jax.nn.silu(z)
    return qmm(out, p["down_proj"])


def mlstm_decode(p: Params, cfg: ModelConfig, x: jax.Array, cache: Dict
                 ) -> Tuple[jax.Array, Dict]:
    b = x.shape[0]
    di, nh, dh = mlstm_dims(cfg)
    up = qmm(x, p["up_proj"])
    x_m, z = up[..., :di], up[..., di:]

    conv_buf = jnp.concatenate([cache["conv"], x_m], axis=1)
    x_c = jnp.einsum("bkc,kc->bc", conv_buf, p["conv_w"]) + p["conv_b"]
    x_c = jax.nn.silu(x_c)[:, None, :]
    q, k, v, i_raw, f_raw = _mlstm_qkvif(p, cfg, x_c[:, 0])
    o = jax.nn.sigmoid(qmm(x_m, p["w_o"]))

    state = (cache["C"], cache["n"], cache["m"])
    state, h = _mlstm_cell(q, k, v, i_raw, f_raw, state)
    h = h.reshape(b, 1, di).astype(x.dtype)
    h = rms_norm(h, p["hnorm"], cfg.norm_eps) * o
    out = qmm(h * jax.nn.silu(z), p["down_proj"])
    return out, {"C": state[0], "n": state[1], "m": state[2],
                 "conv": conv_buf[:, 1:, :]}


def mlstm_serve_step(p: Params, cfg: ModelConfig, x: jax.Array,
                     cache: Dict, valid: jax.Array
                     ) -> Tuple[jax.Array, Dict]:
    """Masked multi-token mLSTM step; see `mamba2_serve_step` for the
    lane-masking contract (x: (b, s, d), valid: (b, s))."""
    b, s, _ = x.shape
    di, nh, dh = mlstm_dims(cfg)
    up = qmm(x, p["up_proj"])
    x_m, z = up[..., :di], up[..., di:]
    o = jax.nn.sigmoid(qmm(x_m, p["w_o"]))

    def step(carry, inp):
        C, n, m, conv = carry
        xm_t, v_t = inp                    # (b, di), (b,)
        conv_buf = jnp.concatenate([conv, xm_t[:, None, :]], axis=1)
        xc = jnp.einsum("bkc,kc->bc", conv_buf, p["conv_w"]) + p["conv_b"]
        xc = jax.nn.silu(xc)
        q, k, v, i_raw, f_raw = _mlstm_qkvif(p, cfg, xc)
        (C2, n2, m2), h = _mlstm_cell(q, k, v, i_raw, f_raw, (C, n, m))
        C = jnp.where(v_t[:, None, None, None], C2, C)
        n = jnp.where(v_t[:, None, None], n2, n)
        m = jnp.where(v_t[:, None], m2, m)
        conv = jnp.where(v_t[:, None, None], conv_buf[:, 1:, :], conv)
        return (C, n, m, conv), h

    conv0 = cache["conv"].astype(jnp.promote_types(cache["conv"].dtype,
                                                   x_m.dtype))
    (C, n, m, conv), hs = jax.lax.scan(
        step, (cache["C"], cache["n"], cache["m"], conv0),
        (x_m.swapaxes(0, 1), valid.swapaxes(0, 1)))
    h = hs.swapaxes(0, 1).reshape(b, s, di).astype(x.dtype)
    h = rms_norm(h, p["hnorm"], cfg.norm_eps) * o
    out = qmm(h * jax.nn.silu(z), p["down_proj"])
    return out, {"C": C, "n": n, "m": m, "conv": conv}


def mlstm_cache_spec(cfg: ModelConfig, batch: int):
    di, nh, dh = mlstm_dims(cfg)
    return {
        "C": jax.ShapeDtypeStruct((batch, nh, dh, dh), jnp.float32),
        "n": jax.ShapeDtypeStruct((batch, nh, dh), jnp.float32),
        "m": jax.ShapeDtypeStruct((batch, nh), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, cfg.ssm.conv_width - 1, di),
                                     jnp.bfloat16),
    }


# ============================================================================
# sLSTM (xLSTM scalar-memory block with recurrent gating)
# ============================================================================
def slstm_dims(cfg: ModelConfig):
    nh = cfg.ssm.mlstm_heads
    return cfg.d_model, nh, cfg.d_model // nh


def slstm_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, nh, dh = slstm_dims(cfg)
    f_up = int(cfg.ssm.proj_factor_slstm * d)
    return {
        "w_gates": ParamSpec((d, 4 * d), axes=(FSDP, NONE)),
        "r_gates": ParamSpec((nh, dh, 4 * dh), axes=(NONE, NONE, TP),
                             scale=1.0 / math.sqrt(dh)),
        "b_gates": ParamSpec((4 * d,), axes=(NONE,), init="zeros"),
        "gnorm": ParamSpec((d,), axes=(NONE,), init="ones"),
        "ffn_up": ParamSpec((d, 2 * f_up), axes=(FSDP, TP)),
        "ffn_down": ParamSpec((f_up, d), axes=(TP, FSDP)),
    }


def _slstm_cell(p, cfg, xt, state):
    """xt: (b,d). state {c,n,h,m}: (b,d)/(b,nh)."""
    d, nh, dh = slstm_dims(cfg)
    b = xt.shape[0]
    c, n, h_prev, m = state
    hx = h_prev.reshape(b, nh, dh)
    rec = jnp.einsum("bhd,hde->bhe", hx, p["r_gates"]).reshape(b, 4 * d)
    g = (xt @ p["w_gates"] + p["b_gates"]).astype(jnp.float32) + \
        rec.astype(jnp.float32)
    zr, ir, fr, orr = jnp.split(g, 4, axis=-1)
    ir_h = ir.reshape(b, nh, dh).mean(-1)          # per-head scalar gates
    fr_h = fr.reshape(b, nh, dh).mean(-1)
    m_new = jnp.maximum(fr_h + m, ir_h)
    i_p = jnp.exp(ir_h - m_new)[..., None]
    f_p = jnp.exp(fr_h + m - m_new)[..., None]
    cz = jnp.tanh(zr).reshape(b, nh, dh)
    ch = c.reshape(b, nh, dh)
    nh_ = n.reshape(b, nh, dh)
    c_new = f_p * ch + i_p * cz
    n_new = f_p * nh_ + i_p
    h_new = jax.nn.sigmoid(orr) * (c_new / jnp.maximum(n_new, 1e-6)
                                   ).reshape(b, d)
    return (c_new.reshape(b, d), n_new.reshape(b, d), h_new, m_new), h_new


def slstm_forward(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    b, s, d = x.shape
    _, nh, _ = slstm_dims(cfg)
    tc = min(cfg.ssm.time_chunk, s)
    pad = (-s) % tc
    xp = jnp.pad(x, ((0, 0), (0, pad), (0, 0))) if pad else x
    nc = (s + pad) // tc
    chunks = xp.reshape(b, nc, tc, d).swapaxes(0, 1).astype(jnp.float32)

    def chunk_body(state, xc):
        def step(st, xt):
            return _slstm_cell(p, cfg, xt, st)
        return jax.lax.scan(step, state, xc.swapaxes(0, 1))

    state0 = (jnp.zeros((b, d), jnp.float32), jnp.zeros((b, d), jnp.float32),
              jnp.zeros((b, d), jnp.float32), jnp.full((b, nh), -1e30,
                                                       jnp.float32))
    _, hs = jax.lax.scan(jax.checkpoint(chunk_body, prevent_cse=False),
                         state0, chunks)
    # (nc, tc, b, d) -> (nc*tc, b, d) -> (b, s, d)
    h = hs.reshape(nc * tc, b, d)[:s].swapaxes(0, 1)
    h = h.astype(x.dtype)
    h = rms_norm(h, p["gnorm"], cfg.norm_eps)
    up = qmm(h, p["ffn_up"])
    f_up = up.shape[-1] // 2
    h = jax.nn.gelu(up[..., :f_up]) * up[..., f_up:]
    return qmm(h, p["ffn_down"])


def slstm_decode(p: Params, cfg: ModelConfig, x: jax.Array, cache: Dict
                 ) -> Tuple[jax.Array, Dict]:
    state = (cache["c"], cache["n"], cache["h"], cache["m"])
    state, h = _slstm_cell(p, cfg, x[:, 0].astype(jnp.float32), state)
    h = h[:, None, :].astype(x.dtype)
    h = rms_norm(h, p["gnorm"], cfg.norm_eps)
    up = qmm(h, p["ffn_up"])
    f_up = up.shape[-1] // 2
    h = jax.nn.gelu(up[..., :f_up]) * up[..., f_up:]
    return qmm(h, p["ffn_down"]), {"c": state[0], "n": state[1], "h": state[2],
                               "m": state[3]}


def slstm_serve_step(p: Params, cfg: ModelConfig, x: jax.Array,
                     cache: Dict, valid: jax.Array
                     ) -> Tuple[jax.Array, Dict]:
    """Masked multi-token sLSTM step; see `mamba2_serve_step` for the
    lane-masking contract.  The recurrent gate matmul depends on h_prev
    and stays in the scan; the FFN runs batched over (b, s)."""
    b, s, d = x.shape

    def step(carry, inp):
        xt, v_t = inp
        new, h = _slstm_cell(p, cfg, xt, carry)
        new = tuple(
            jnp.where(v_t.reshape((b,) + (1,) * (a.ndim - 1)), a2, a)
            for a, a2 in zip(carry, new))
        return new, h

    state = (cache["c"], cache["n"], cache["h"], cache["m"])
    state, hs = jax.lax.scan(
        step, state, (x.swapaxes(0, 1).astype(jnp.float32),
                      valid.swapaxes(0, 1)))
    h = hs.swapaxes(0, 1).astype(x.dtype)
    h = rms_norm(h, p["gnorm"], cfg.norm_eps)
    up = qmm(h, p["ffn_up"])
    f_up = up.shape[-1] // 2
    h = jax.nn.gelu(up[..., :f_up]) * up[..., f_up:]
    return qmm(h, p["ffn_down"]), {"c": state[0], "n": state[1],
                                   "h": state[2], "m": state[3]}


def slstm_cache_spec(cfg: ModelConfig, batch: int):
    d, nh, _ = slstm_dims(cfg)
    f32 = jnp.float32
    return {
        "c": jax.ShapeDtypeStruct((batch, d), f32),
        "n": jax.ShapeDtypeStruct((batch, d), f32),
        "h": jax.ShapeDtypeStruct((batch, d), f32),
        "m": jax.ShapeDtypeStruct((batch, nh), f32),
    }
