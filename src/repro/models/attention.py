"""Attention layers: GQA (+bias/QK-norm/softcap/local-global) and MLA.

Two execution paths per flavor:
  * `*_forward`  — full-sequence training/prefill; query-chunked so the
                   32k-prefill score matrix is never fully materialized.
  * `*_decode`   — one-token decode against a KV cache.  For MLA the cache
                   is the compressed latent (EdgeCIM's KV-block streaming
                   applies to a 9x smaller stream — see DESIGN.md SS4).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import (FSDP, NONE, TP, ParamSpec, apply_rope, rms_norm,
                     rope_tables, softcap)
from repro.kernels.ops import qmatmul_xla as qmm
from repro.quant.qarray import maybe_dequantize as deq
from .config import ModelConfig

Params = Dict[str, jax.Array]

Q_CHUNK = 2048      # query-block size for chunked attention
NEG_INF = -1.0e30


# ----------------------------------------------------------------------------
# parameter specs
# ----------------------------------------------------------------------------
def gqa_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, hd = cfg.d_model, cfg.hd()
    sp: Dict[str, ParamSpec] = {
        "wq": ParamSpec((d, cfg.n_heads * hd), axes=(FSDP, TP)),
        "wk": ParamSpec((d, cfg.n_kv_heads * hd), axes=(FSDP, TP)),
        "wv": ParamSpec((d, cfg.n_kv_heads * hd), axes=(FSDP, TP)),
        "wo": ParamSpec((cfg.n_heads * hd, d), axes=(TP, FSDP)),
    }
    if cfg.qkv_bias:
        sp["bq"] = ParamSpec((cfg.n_heads * hd,), axes=(TP,), init="zeros")
        sp["bk"] = ParamSpec((cfg.n_kv_heads * hd,), axes=(TP,), init="zeros")
        sp["bv"] = ParamSpec((cfg.n_kv_heads * hd,), axes=(TP,), init="zeros")
    if cfg.qk_norm:
        sp["q_norm"] = ParamSpec((hd,), axes=(NONE,), init="ones")
        sp["k_norm"] = ParamSpec((hd,), axes=(NONE,), init="ones")
    return sp


def mla_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq": ParamSpec((d, H * qk_dim), axes=(FSDP, TP)),
        "w_dkv": ParamSpec((d, m.kv_lora_rank + m.qk_rope_head_dim),
                           axes=(FSDP, NONE)),
        "ckv_norm": ParamSpec((m.kv_lora_rank,), axes=(NONE,), init="ones"),
        "w_uk": ParamSpec((m.kv_lora_rank, H * m.qk_nope_head_dim),
                          axes=(NONE, TP)),
        "w_uv": ParamSpec((m.kv_lora_rank, H * m.v_head_dim),
                          axes=(NONE, TP)),
        "wo": ParamSpec((H * m.v_head_dim, d), axes=(TP, FSDP)),
    }


def attention_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    return mla_specs(cfg) if cfg.attn_kind == "mla" else gqa_specs(cfg)


# ----------------------------------------------------------------------------
# masked, query-chunked softmax attention core
# ----------------------------------------------------------------------------
def _softmax_attend(q: jax.Array, k: jax.Array, v: jax.Array,
                    mask: jax.Array, scale: float,
                    attn_cap: float) -> jax.Array:
    """q (b,qs,g,qpk,hd) k/v (b,ks,g,hd) mask (qs,ks) -> (b,qs,g,qpk,hd)."""
    scores = jnp.einsum("bqgph,bkgh->bgpqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if attn_cap:
        scores = softcap(scores, attn_cap)
    scores = jnp.where(mask[None, None, None, :, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgpqk,bkgh->bqgph", w.astype(v.dtype), v)
    return out


def _chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                       q_pos: jax.Array, k_pos: jax.Array, window: jax.Array,
                       scale: float, attn_cap: float,
                       unroll: bool = False) -> jax.Array:
    """Causal (optionally windowed) attention, scanned over query chunks.

    q: (b, qs, g, qpk, hd); k, v: (b, ks, g, hd);
    q_pos (qs,), k_pos (ks,) absolute positions; window: scalar (0 = global).
    """
    b, qs, g, qpk, hd = q.shape
    hd_v = v.shape[-1]                    # MLA: value dim != query dim

    def mask_for(qp):
        causal = qp[:, None] >= k_pos[None, :]
        local = jnp.where(window > 0,
                          qp[:, None] - k_pos[None, :] < window, True)
        return causal & local

    if qs <= Q_CHUNK:
        return _softmax_attend(q, k, v, mask_for(q_pos), scale, attn_cap)

    n_chunks = math.ceil(qs / Q_CHUNK)
    pad = n_chunks * Q_CHUNK - qs
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, pad), constant_values=q_pos[-1])
    qc = q.reshape(b, n_chunks, Q_CHUNK, g, qpk, hd).swapaxes(0, 1)
    pc = q_pos.reshape(n_chunks, Q_CHUNK)

    def body(_, args):
        qi, pi = args
        return None, _softmax_attend(qi, k, v, mask_for(pi), scale, attn_cap)

    from .common import scan_layers
    _, out = scan_layers(body, None, (qc, pc), unroll)
    out = out.swapaxes(0, 1).reshape(b, n_chunks * Q_CHUNK, g, qpk, hd_v)
    return out[:, :qs]


# ----------------------------------------------------------------------------
# GQA
# ----------------------------------------------------------------------------
def _qkv(p: Params, cfg: ModelConfig, x: jax.Array
         ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    b, s, _ = x.shape
    hd = cfg.hd()
    q = qmm(x, p["wq"])
    k = qmm(x, p["wk"])
    v = qmm(x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(b, s, cfg.n_heads, hd)
    k = k.reshape(b, s, cfg.n_kv_heads, hd)
    v = v.reshape(b, s, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def gqa_forward(p: Params, cfg: ModelConfig, x: jax.Array,
                positions: jax.Array, is_local) -> jax.Array:
    """Full-sequence attention. positions: (s,) int32; is_local: scalar bool."""
    b, s, _ = x.shape
    hd, g, qpk = cfg.hd(), cfg.n_kv_heads, cfg.q_per_kv()
    q, k, v = _qkv(p, cfg, x)

    theta_local = cfg.rope_theta_local or cfg.rope_theta
    theta = jnp.where(is_local, theta_local, cfg.rope_theta)
    # rope tables depend on a traced theta -> compute inline
    freqs = jnp.exp(jnp.arange(0, hd, 2, dtype=jnp.float32) / hd
                    * -jnp.log(theta))
    ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    qg = q.reshape(b, s, g, qpk, hd)
    window = jnp.where(is_local, cfg.local_window, 0)
    out = _chunked_attention(qg, k, v, positions, positions, window,
                             1.0 / math.sqrt(hd), cfg.attn_softcap,
                             unroll=cfg.unroll)
    out = out.reshape(b, s, cfg.n_heads * hd)
    return qmm(out, p["wo"]), {"k": k, "v": v}


def gqa_decode(p: Params, cfg: ModelConfig, x: jax.Array, cache: Dict,
               pos: jax.Array, is_local) -> Tuple[jax.Array, Dict]:
    """One-token decode. x: (b, 1, d); cache {k,v}: (b, S, g, hd); pos scalar."""
    b = x.shape[0]
    hd, g, qpk = cfg.hd(), cfg.n_kv_heads, cfg.q_per_kv()
    S = cache["k"].shape[1]
    q, k, v = _qkv(p, cfg, x)

    theta_local = cfg.rope_theta_local or cfg.rope_theta
    theta = jnp.where(is_local, theta_local, cfg.rope_theta)
    freqs = jnp.exp(jnp.arange(0, hd, 2, dtype=jnp.float32) / hd
                    * -jnp.log(theta))
    posf = pos.astype(jnp.float32)
    cos = jnp.cos(posf * freqs)[None, :]
    sin = jnp.sin(posf * freqs)[None, :]
    q = apply_rope(q, cos[None], sin[None])
    k = apply_rope(k, cos[None], sin[None])

    # Attend over the STALE cache (positions < pos) plus a rank-1 term for
    # the fresh token, so the cache update is a pure output write that
    # never feeds the attention einsum (SSPerf iteration c4: keeps SPMD
    # from materializing converted copies of the cache around the DUS).
    k_pos = jnp.arange(S)
    valid = k_pos < pos                                 # strictly stale
    window = jnp.where(is_local, cfg.local_window, 0)
    local_ok = jnp.where(window > 0, pos - k_pos < window, True)
    mask = valid & local_ok                             # (S,)

    qg = q.reshape(b, 1, g, qpk, hd)
    scale = 1.0 / math.sqrt(hd)
    scores_c = jnp.einsum("bqgph,bkgh->bgpqk", qg,
                          cache["k"].astype(qg.dtype),
                          preferred_element_type=jnp.float32) * scale
    scores_n = jnp.einsum("bqgph,bqgh->bgpq", qg.astype(jnp.float32),
                          k.astype(jnp.float32))[..., None] * scale
    # (b,g,p,1,1): the fresh token's score per query head
    if cfg.attn_softcap:
        scores_c = softcap(scores_c, cfg.attn_softcap)
        scores_n = softcap(scores_n, cfg.attn_softcap)
    scores_c = jnp.where(mask[None, None, None, None, :], scores_c, NEG_INF)

    m = jnp.maximum(jnp.max(scores_c, axis=-1, keepdims=True), scores_n)
    e_c = jnp.exp(scores_c - m)
    e_n = jnp.exp(scores_n - m)
    denom = jnp.sum(e_c, axis=-1, keepdims=True) + e_n
    out = jnp.einsum("bgpqk,bkgh->bqgph", (e_c / denom).astype(qg.dtype),
                     cache["v"].astype(qg.dtype))
    w_n = (e_n / denom)[..., 0]                         # (b,g,p,1)
    out = out + jnp.einsum("bgpq,bqgh->bqgph", w_n.astype(qg.dtype),
                           v.astype(qg.dtype))

    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, pos, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, pos, 0, 0))
    out = qmm(out.reshape(b, 1, cfg.n_heads * hd), p["wo"])
    return out, {"k": ck, "v": cv}


def _page_scatter(pool: jax.Array, vals: jax.Array, tables: jax.Array,
                  slots: jax.Array, n_new: jax.Array) -> jax.Array:
    """Write per-token rows into a paged pool.

    pool: (n_pages, page_size, ...); vals: (b, s, ...); tables:
    (b, max_pages); slots: (b, s) absolute positions; n_new: (b,) valid
    new tokens per sequence (padding lanes write out-of-bounds and drop).
    """
    b, s = vals.shape[0], vals.shape[1]
    n_pages, ps = pool.shape[0], pool.shape[1]
    page = tables[jnp.arange(b)[:, None], slots // ps]           # (b, s)
    page = jnp.where(jnp.arange(s)[None, :] < n_new[:, None], page, n_pages)
    off = slots % ps
    return pool.at[page, off].set(vals.astype(pool.dtype), mode="drop")


def _quantize_kv_rows(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-(token, kv-head) symmetric INT8: x (b, s, g, hd) -> (values
    rounded to [-127, 127] still in float, scales (b, s, g) f16).  The
    STORED f16 scale is what divides, so pool int8 x pool scale
    round-trips without a second rounding."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = (jnp.maximum(absmax, 1e-8) / 127.0).astype(jnp.float16)
    q = jnp.clip(jnp.round(x.astype(jnp.float32)
                           / scale[..., None].astype(jnp.float32)),
                 -127.0, 127.0)
    return q, scale


def gqa_paged_step(p: Params, cfg: ModelConfig, x: jax.Array, cache: Dict,
                   tables: jax.Array, lengths: jax.Array, n_new: jax.Array,
                   is_local, verify: bool = False) -> Tuple[jax.Array, Dict]:
    """Chunked prefill / decode against a paged KV pool.

    x: (b, s, d) — s == 1 is decode, s > 1 a prefill chunk (right-padded;
    `n_new[i]` of the s tokens are real).  cache {k, v}:
    (n_pages, page_size, g, hd) page pools shared by the whole batch;
    tables: (b, max_pages) int32; lengths: (b,) tokens already cached.
    Per-sequence positions — no shared `pos` scalar, so one sequence's
    prefill can never clobber another's rows (the dense engine's
    `_prefill_slot` bug).

    verify=True (speculative decode) routes the s > 1 window through the
    multi-query flash kernel — one pass over the sequence's pages
    scores all s draft positions — instead of the chunk path's full
    page gather.  Same math (the intra-window causal mask is identical);
    sliding-window models carry a traced `is_local` and keep the masked
    gather path.
    """
    b, s, _ = x.shape
    hd, g, qpk = cfg.hd(), cfg.n_kv_heads, cfg.q_per_kv()
    ps = cache["k"].shape[1]
    S = tables.shape[1] * ps
    q, k, v = _qkv(p, cfg, x)

    theta_local = cfg.rope_theta_local or cfg.rope_theta
    theta = jnp.where(is_local, theta_local, cfg.rope_theta)
    slots = lengths[:, None] + jnp.arange(s)[None, :]            # (b, s)
    cos, sin = rope_tables(slots, hd, theta)                     # (b, s, hd/2)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    quant_kv = "k_scale" in cache
    if quant_kv:
        # per-token INT8 pools: scale pages ride the same block tables
        # (COW/fork/trim move them with their K/V pages for free)
        kq, ks = _quantize_kv_rows(k)
        vq, vs = _quantize_kv_rows(v)
        ck = _page_scatter(cache["k"], kq, tables, slots, n_new)
        cv = _page_scatter(cache["v"], vq, tables, slots, n_new)
        cks = _page_scatter(cache["k_scale"], ks, tables, slots, n_new)
        cvs = _page_scatter(cache["v_scale"], vs, tables, slots, n_new)
        out_cache = {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs}
    else:
        ck = _page_scatter(cache["k"], k, tables, slots, n_new)
        cv = _page_scatter(cache["v"], v, tables, slots, n_new)
        cks = cvs = None
        out_cache = {"k": ck, "v": cv}
    total = lengths + n_new                                      # (b,)
    window = int(cfg.local_window or 0)
    scale = 1.0 / math.sqrt(hd)

    if s == 1 and not window:
        # decode fast path: block-table Pallas kernel on TPU, gather
        # reference elsewhere.  Models with sliding-window layers carry a
        # traced `is_local`, which needs the masked gather path below.
        from repro.kernels.ops import paged_decode_attention
        qg = q.reshape(b, g, qpk, hd)
        out_g = paged_decode_attention(qg, ck, cv, tables, total, 0,
                                       cfg.attn_softcap,
                                       k_scales=cks, v_scales=cvs)
        out = out_g.reshape(b, 1, cfg.n_heads * hd).astype(x.dtype)
        return qmm(out, p["wo"]), out_cache

    if verify and not window:
        # speculative-verify fast path: all s window positions in one
        # multi-query pass, no (b, S, ...) gather materialized
        from repro.kernels.ops import paged_verify_attention
        qg = q.reshape(b, s, g, qpk, hd)
        out_g = paged_verify_attention(qg, ck, cv, tables, lengths, 0,
                                       cfg.attn_softcap,
                                       k_scales=cks, v_scales=cvs)
        out = out_g.reshape(b, s, cfg.n_heads * hd).astype(x.dtype)
        return qmm(out, p["wo"]), out_cache

    # chunk path: gather the sequence's pages back to a contiguous view
    if quant_kv:
        kg = (ck[tables].astype(jnp.float32)
              * cks[tables][..., None].astype(jnp.float32)
              ).reshape(b, S, g, hd)
        vg = (cv[tables].astype(jnp.float32)
              * cvs[tables][..., None].astype(jnp.float32)
              ).reshape(b, S, g, hd)
    else:
        kg = ck[tables].reshape(b, S, g, hd)
        vg = cv[tables].reshape(b, S, g, hd)
    qg = q.reshape(b, s, g, qpk, hd)
    scores = jnp.einsum("bqgph,bkgh->bgpqk", qg, kg.astype(qg.dtype),
                        preferred_element_type=jnp.float32) * scale
    if cfg.attn_softcap:
        scores = softcap(scores, cfg.attn_softcap)
    k_pos = jnp.arange(S)
    mask = (k_pos[None, None, :] <= slots[:, :, None]) \
        & (k_pos[None, None, :] < total[:, None, None])          # (b, s, S)
    if window:
        local_ok = slots[:, :, None] - k_pos[None, None, :] < window
        mask = mask & jnp.where(is_local, local_ok, True)
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgpqk,bkgh->bqgph", w.astype(vg.dtype), vg)
    out = out.reshape(b, s, cfg.n_heads * hd).astype(x.dtype)
    return qmm(out, p["wo"]), out_cache


def mla_paged_step(p: Params, cfg: ModelConfig, x: jax.Array, cache: Dict,
                   tables: jax.Array, lengths: jax.Array, n_new: jax.Array,
                   is_local, verify: bool = False) -> Tuple[jax.Array, Dict]:
    """Paged absorbed-MLA step over latent pools.

    cache {c_kv: (n_pages, ps, r), k_rope: (n_pages, ps, rope_d)}.
    The latent gather already scores every window position with the
    correct intra-window causal mask, so `verify` needs no separate
    path (the latent stream is ~9x smaller than GQA K/V — the gather
    the multi-query kernel exists to avoid is cheap here).
    """
    m = cfg.mla
    b, s, _ = x.shape
    H = cfg.n_heads
    nope, rope_d, vd = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    r = m.kv_lora_rank
    ps = cache["c_kv"].shape[1]
    S = tables.shape[1] * ps

    q = qmm(x, p["wq"]).reshape(b, s, H, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]

    dkv = qmm(x, p["w_dkv"])
    c_new = rms_norm(dkv[..., :r], p["ckv_norm"], cfg.norm_eps)
    kr_new = dkv[..., r:][:, :, None, :]                         # (b,s,1,rd)

    slots = lengths[:, None] + jnp.arange(s)[None, :]
    cos, sin = rope_tables(slots, rope_d, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    kr_new = apply_rope(kr_new, cos, sin)

    c_pool = _page_scatter(cache["c_kv"], c_new, tables, slots, n_new)
    kr_pool = _page_scatter(cache["k_rope"], kr_new[:, :, 0, :], tables,
                            slots, n_new)
    c_all = c_pool[tables].reshape(b, S, r)
    kr_all = kr_pool[tables].reshape(b, S, rope_d)

    w_uk = deq(p["w_uk"]).reshape(r, H, nope)
    q_lat = jnp.einsum("bqhn,rhn->bqhr", q_nope, w_uk)
    scores = (jnp.einsum("bqhr,bkr->bhqk", q_lat, c_all.astype(q_lat.dtype),
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bqhr,bkr->bhqk", q_rope,
                           kr_all.astype(q_rope.dtype),
                           preferred_element_type=jnp.float32))
    scores = scores / math.sqrt(nope + rope_d)
    k_pos = jnp.arange(S)
    total = lengths + n_new
    mask = (k_pos[None, None, :] <= slots[:, :, None]) \
        & (k_pos[None, None, :] < total[:, None, None])
    scores = jnp.where(mask[:, None, :, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)

    o_lat = jnp.einsum("bhqk,bkr->bqhr", w.astype(c_all.dtype), c_all)
    w_uv = deq(p["w_uv"]).reshape(r, H, vd)
    out = jnp.einsum("bqhr,rhv->bqhv", o_lat.astype(x.dtype), w_uv)
    out = qmm(out.reshape(b, s, H * vd), p["wo"])
    return out, {"c_kv": c_pool, "k_rope": kr_pool}


# ----------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ----------------------------------------------------------------------------
def mla_forward(p: Params, cfg: ModelConfig, x: jax.Array,
                positions: jax.Array, is_local) -> jax.Array:
    """Training path: decompress the latent into per-head K/V."""
    m = cfg.mla
    b, s, _ = x.shape
    H = cfg.n_heads
    nope, rope_d, vd = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim

    q = qmm(x, p["wq"]).reshape(b, s, H, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]

    dkv = qmm(x, p["w_dkv"])
    c_kv = rms_norm(dkv[..., :m.kv_lora_rank], p["ckv_norm"], cfg.norm_eps)
    k_rope = dkv[..., m.kv_lora_rank:]                  # (b, s, rope_d)

    cos, sin = rope_tables(positions, rope_d, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)  # (b,s,1,rope_d)

    k_nope = qmm(c_kv, p["w_uk"]).reshape(b, s, H, nope)
    v = qmm(c_kv, p["w_uv"]).reshape(b, s, H, vd)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, s, H, rope_d))],
                        axis=-1)
    qf = jnp.concatenate([q_nope, q_rope], axis=-1)

    qg = qf.reshape(b, s, H, 1, nope + rope_d)
    out = _chunked_attention(qg, k, v, positions, positions,
                             jnp.int32(0), 1.0 / math.sqrt(nope + rope_d),
                             cfg.attn_softcap, unroll=cfg.unroll)
    out = out.reshape(b, s, H * vd)
    return qmm(out, p["wo"]), {"c_kv": c_kv, "k_rope": k_rope[:, :, 0, :]}


def mla_decode(p: Params, cfg: ModelConfig, x: jax.Array, cache: Dict,
               pos: jax.Array, is_local) -> Tuple[jax.Array, Dict]:
    """Absorbed decode over the compressed cache {c_kv: (b,S,r), k_rope}."""
    m = cfg.mla
    b = x.shape[0]
    H = cfg.n_heads
    nope, rope_d, vd = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    r = m.kv_lora_rank
    S = cache["c_kv"].shape[1]

    q = qmm(x, p["wq"]).reshape(b, 1, H, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]

    dkv = qmm(x, p["w_dkv"])
    c_new = rms_norm(dkv[..., :r], p["ckv_norm"], cfg.norm_eps)
    krope_new = dkv[..., r:][:, :, None, :]

    posf = pos.astype(jnp.float32)
    freqs = 1.0 / (cfg.rope_theta ** (jnp.arange(0, rope_d, 2,
                                                 dtype=jnp.float32) / rope_d))
    cos = jnp.cos(posf * freqs)[None, :]
    sin = jnp.sin(posf * freqs)[None, :]
    q_rope = apply_rope(q_rope, cos[None], sin[None])
    krope_new = apply_rope(krope_new, cos[None], sin[None])

    c_cache = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_new.astype(cache["c_kv"].dtype), (0, pos, 0))
    kr_cache = jax.lax.dynamic_update_slice(
        cache["k_rope"], krope_new[:, :, 0, :].astype(cache["k_rope"].dtype),
        (0, pos, 0))

    # absorb: q_lat = q_nope @ W_UK^T  (per head)
    w_uk = deq(p["w_uk"]).reshape(r, H, nope)
    q_lat = jnp.einsum("bqhn,rhn->bqhr", q_nope, w_uk)

    scores = (jnp.einsum("bqhr,bkr->bhqk", q_lat,
                         c_cache.astype(q_lat.dtype),
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bqhr,bkr->bhqk", q_rope,
                           kr_cache.astype(q_rope.dtype),
                           preferred_element_type=jnp.float32))
    scores = scores / math.sqrt(nope + rope_d)
    valid = jnp.arange(S) <= pos
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)

    o_lat = jnp.einsum("bhqk,bkr->bqhr", w.astype(c_cache.dtype), c_cache)
    w_uv = deq(p["w_uv"]).reshape(r, H, vd)
    out = jnp.einsum("bqhr,rhv->bqhv", o_lat.astype(x.dtype), w_uv)
    out = qmm(out.reshape(b, 1, H * vd), p["wo"])
    return out, {"c_kv": c_cache, "k_rope": kr_cache}


# ----------------------------------------------------------------------------
# dispatch + cache construction
# ----------------------------------------------------------------------------
def attn_forward(p, cfg, x, positions, is_local):
    fn = mla_forward if cfg.attn_kind == "mla" else gqa_forward
    return fn(p, cfg, x, positions, is_local)


def attn_decode(p, cfg, x, cache, pos, is_local):
    fn = mla_decode if cfg.attn_kind == "mla" else gqa_decode
    return fn(p, cfg, x, cache, pos, is_local)


def attn_paged_step(p, cfg, x, cache, tables, lengths, n_new, is_local,
                    verify: bool = False):
    fn = mla_paged_step if cfg.attn_kind == "mla" else gqa_paged_step
    return fn(p, cfg, x, cache, tables, lengths, n_new, is_local,
              verify=verify)


def paged_cache_spec(cfg: ModelConfig, n_pages: int, page_size: int,
                     dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    """Shape/dtype of one layer's paged KV pool (shared by all sequences).

    dtype == int8 requests the quantized pool layout: int8 K/V plus f16
    per-(token, kv-head) scale pools keyed "k_scale"/"v_scale".  Every
    leaf keeps the page axis first, so the allocator's page-copy (COW),
    fork, and trim move scales together with their pages — the block
    table stays the single source of truth.
    """
    if cfg.attn_kind == "mla":
        if dtype == jnp.int8:
            # the latent stream is already ~9x smaller than GQA K/V and
            # is consumed through matmuls (not per-token rows); keep fp
            raise ValueError(
                "int8 paged KV is not supported for MLA latent pools")
        m = cfg.mla
        return {
            "c_kv": jax.ShapeDtypeStruct((n_pages, page_size,
                                          m.kv_lora_rank), dtype),
            "k_rope": jax.ShapeDtypeStruct((n_pages, page_size,
                                            m.qk_rope_head_dim), dtype),
        }
    kv = jax.ShapeDtypeStruct((n_pages, page_size, cfg.n_kv_heads,
                               cfg.hd()), dtype)
    spec = {"k": kv, "v": kv}
    if dtype == jnp.int8:
        sc = jax.ShapeDtypeStruct((n_pages, page_size, cfg.n_kv_heads),
                                  jnp.float16)
        spec["k_scale"] = sc
        spec["v_scale"] = sc
    return spec


def empty_cache_spec(cfg: ModelConfig, batch: int, max_seq: int,
                     dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    """Shape/dtype of one layer's KV cache."""
    if cfg.attn_kind == "mla":
        m = cfg.mla
        return {
            "c_kv": jax.ShapeDtypeStruct((batch, max_seq, m.kv_lora_rank), dtype),
            "k_rope": jax.ShapeDtypeStruct((batch, max_seq, m.qk_rope_head_dim),
                                           dtype),
        }
    return {
        "k": jax.ShapeDtypeStruct((batch, max_seq, cfg.n_kv_heads, cfg.hd()),
                                  dtype),
        "v": jax.ShapeDtypeStruct((batch, max_seq, cfg.n_kv_heads, cfg.hd()),
                                  dtype),
    }
