"""Model substrate: parameter specs w/ logical sharding axes, norms, RoPE.

Parameters are declared as `ParamSpec` pytrees (shape + dtype + logical
axes + init).  This lets the same definition serve three consumers:
  * `init_params`      — materialize real arrays (smoke tests, examples)
  * `spec_structs`     — jax.ShapeDtypeStruct stand-ins (multi-pod dry-run:
                         a 235B model is lowered without allocating a byte)
  * `logical_sharding` — NamedSharding per leaf from mesh rules (dist/axes)
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any

# logical axis names (mapped to mesh axes in dist/axes.py)
BATCH = "batch"      # activation batch            -> (pod, data)
FSDP = "fsdp"        # param fully-sharded dim     -> data
TP = "tp"            # tensor-parallel dim          -> model
EXPERT = "expert"    # MoE expert dim               -> model
KV_SEQ = "kv_seq"    # decode KV sequence (split-K) -> model
SEQ = "seq"          # long-context activation seq  -> data
LAYERS = "layers"    # stacked-scan layer dim       -> replicated
NONE = None


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    dtype: Any = jnp.bfloat16
    axes: Tuple[Optional[str], ...] = ()
    init: str = "normal"          # normal | zeros | ones | embed
    scale: Optional[float] = None  # None => 1/sqrt(fan_in)

    def __post_init__(self):
        assert len(self.axes) == len(self.shape), (self.shape, self.axes)

    def fan_in(self) -> int:
        if len(self.shape) <= 1:
            return self.shape[0] if self.shape else 1
        return int(np.prod(self.shape[:-1]))

    def struct(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)

    def materialize(self, key: jax.Array) -> jax.Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        if self.init == "embed":
            std = self.scale if self.scale is not None else 1.0
            return (jax.random.normal(key, self.shape, jnp.float32) * std
                    ).astype(self.dtype)
        std = self.scale if self.scale is not None else 1.0 / math.sqrt(
            max(self.fan_in(), 1))
        return (jax.random.normal(key, self.shape, jnp.float32) * std
                ).astype(self.dtype)

    def stacked(self, n: int) -> "ParamSpec":
        """Prepend a scanned-layers dim."""
        return dataclasses.replace(self, shape=(n, *self.shape),
                                   axes=(NONE, *self.axes))


def is_spec(x: Any) -> bool:
    return isinstance(x, ParamSpec)


def tree_map_specs(fn: Callable[[ParamSpec], Any], tree: Pytree) -> Pytree:
    return jax.tree_util.tree_map(fn, tree, is_leaf=is_spec)


def stack_specs(tree: Pytree, n: int) -> Pytree:
    return tree_map_specs(lambda s: s.stacked(n), tree)


def spec_structs(tree: Pytree) -> Pytree:
    return tree_map_specs(lambda s: s.struct(), tree)


def spec_axes(tree: Pytree) -> Pytree:
    return tree_map_specs(lambda s: s.axes, tree)


def init_params(tree: Pytree, key: jax.Array,
                dtype_override: Any = None) -> Pytree:
    leaves, treedef = jax.tree_util.tree_flatten(tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, s in zip(keys, leaves):
        arr = s.materialize(k)
        if dtype_override is not None and jnp.issubdtype(arr.dtype, jnp.floating):
            arr = arr.astype(dtype_override)
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


def param_count(tree: Pytree) -> int:
    leaves = jax.tree_util.tree_leaves(tree, is_leaf=is_spec)
    return sum(int(np.prod(s.shape)) for s in leaves)


# ----------------------------------------------------------------------------
# numerics blocks
# ----------------------------------------------------------------------------
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6,
             scale_plus_one: bool = False) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    w = scale.astype(jnp.float32)
    if scale_plus_one:          # gemma convention: weight stored as (w - 1)
        w = w + 1.0
    return (y * w).astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(x.dtype)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    return cap * jnp.tanh(x / cap)


def rope_tables(positions: jax.Array, dim: int, theta: float = 10000.0
                ) -> Tuple[jax.Array, jax.Array]:
    """cos/sin tables for rotary embedding. positions: (...,) int32."""
    assert dim % 2 == 0
    freqs = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., dim/2)
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., seq, heads, head_dim); cos/sin: (..., seq, head_dim/2).

    Split-halves convention (llama/gemma style).
    """
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]
    s = sin[..., None, :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([xf1 * c - xf2 * s, xf2 * c + xf1 * s],
                           axis=-1).astype(x.dtype)


def swish(x: jax.Array) -> jax.Array:
    return x * jax.nn.sigmoid(x)


ACTIVATIONS: Dict[str, Callable[[jax.Array], jax.Array]] = {
    "silu": swish,
    "gelu": jax.nn.gelu,
    "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True),
    "relu": jax.nn.relu,
}


def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       ignore_id: int = -100) -> jax.Array:
    """Mean CE over non-ignored positions. logits (b,s,v), labels (b,s)."""
    lf = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(
        lf, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = logz - gold
    mask = (labels != ignore_id).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def scan_layers(body, carry, xs, unroll: bool = False, length=None):
    """lax.scan, or a python loop producing identical results when
    `unroll` (used by roofline probes: XLA cost analysis counts a
    while-loop body once, an unrolled graph counts every layer)."""
    if not unroll:
        return jax.lax.scan(body, carry, xs, length=length)
    if length is None:
        length = jax.tree_util.tree_leaves(xs)[0].shape[0]
    ys = []
    for i in range(length):
        x_i = jax.tree_util.tree_map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and all(y is not None for y in ys):
        ys = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys
