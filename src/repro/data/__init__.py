"""Deterministic synthetic data pipeline."""
from .synthetic import SyntheticLM, DataConfig

__all__ = ["SyntheticLM", "DataConfig"]
