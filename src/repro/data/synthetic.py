"""Deterministic, seekable synthetic token pipeline.

A fixed random Markov chain over the vocabulary generates sequences with
learnable structure (a model that learns the bigram table drives loss
well below the unigram entropy — the quickstart example shows this).

Determinism + seekability are the fault-tolerance substrate: batch `i` is
a pure function of (seed, i), so a restarted/rescaled job resumes from the
checkpointed cursor with bit-identical data order, and each DP shard draws
its own slice without coordination (no data server to fail).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int = 256
    seq_len: int = 128
    global_batch: int = 8
    seed: int = 0
    branching: int = 8      # out-degree of the Markov chain


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # sparse row-stochastic transition structure
        self.next_tokens = rng.integers(
            0, cfg.vocab, size=(cfg.vocab, cfg.branching), dtype=np.int64)
        logits = rng.standard_normal((cfg.vocab, cfg.branching))
        p = np.exp(logits - logits.max(1, keepdims=True))
        self.next_p = p / p.sum(1, keepdims=True)

    # ------------------------------------------------------------------
    def batch(self, index: int, shard: int = 0, n_shards: int = 1
              ) -> Dict[str, np.ndarray]:
        """Batch `index`, data-parallel shard `shard` of `n_shards`.
        Pure function of (seed, index, shard) — seekable and elastic:
        re-sharding to a different n_shards re-partitions the same global
        batch."""
        cfg = self.cfg
        assert cfg.global_batch % n_shards == 0
        bs = cfg.global_batch // n_shards
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, index, shard]))
        tokens = np.empty((bs, cfg.seq_len + 1), np.int64)
        tokens[:, 0] = rng.integers(0, cfg.vocab, size=bs)
        for t in range(cfg.seq_len):
            cur = tokens[:, t]
            # vectorized categorical draw over the branching table
            u = rng.random(bs)
            cdf = np.cumsum(self.next_p[cur], axis=1)
            choice = (u[:, None] < cdf).argmax(axis=1)
            tokens[:, t + 1] = self.next_tokens[cur, choice]
        return {
            "tokens": tokens[:, :-1].astype(np.int32),
            "labels": tokens[:, 1:].astype(np.int32),
        }

    def stream(self, start_index: int = 0, shard: int = 0, n_shards: int = 1
               ) -> Iterator[Tuple[int, Dict[str, np.ndarray]]]:
        i = start_index
        while True:
            yield i, self.batch(i, shard, n_shards)
            i += 1

    def bigram_entropy(self) -> float:
        """Achievable loss floor (nats/token) for a perfect bigram model."""
        h = -(self.next_p * np.log(self.next_p)).sum(axis=1)
        return float(h.mean())
